//! Job launcher — the LSF/`bsub` substitution (§4.1.2).
//!
//! The paper's launcher runs on the cluster front end: it starts the MXNET
//! scheduler first, broadcasts its address, then submits each MPI client as
//! a separate `mpirun` job, with `#servers` tunable down to zero for pure
//! MPI. This launcher does the same with threads: scheduler, PS server
//! group, then one [`World`](crate::mpisim::World) per client whose worker
//! threads each get a fully wired [`WorkerCtx`] (PS rank, client id, MPI
//! communicator, KVStore endpoint).

use crate::collectives::AlgoKind;
use crate::compress::Codec;
use crate::config::{Algo, ExperimentConfig};
use crate::engine::Engine;
use crate::kvstore::{KvType, KvWorker};
use crate::mpisim::{Comm, World};
use crate::netsim::CostParams;
use crate::ps::{FaultKind, FaultPlan, PsClient, Role, Scheduler, ServerGroup, SyncMode};
use anyhow::{ensure, Context, Result};
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::{Arc, Condvar, Mutex};

/// Shape of a job: the launcher's CLI parameters (§4.1.2).
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub workers: usize,
    pub servers: usize,
    pub clients: usize,
    pub ktype: KvType,
    pub server_mode: SyncMode,
    /// Engine threads per worker.
    pub engine_threads: usize,
    /// Intra-client allreduce schedule (the `collective` config knob).
    pub collective: AlgoKind,
    /// Gradient-fusion bucket cap in bytes (0 disables).
    pub fusion_bytes: usize,
    /// Rings for the multi-ring tensor allreduce (§6.3.2).
    pub rings: usize,
    /// Group size for the hierarchical schedule.
    pub group: usize,
    /// Devices per worker (k): each worker's kvstore runs the local tier
    /// over k per-device buffers before the wire hop (1 = no device tier).
    pub devices: usize,
    /// Cost-model constants the `Auto` schedule tunes against.
    pub cost: CostParams,
    /// Gradient codec (the compression plane; identity = uncompressed).
    pub codec: Codec,
    /// `topk` codec keep-ratio (ignored by the other codecs).
    pub topk_ratio: f64,
    /// Scripted churn (empty = the static job of the original launcher).
    /// MPI kvstore types only: elasticity is the PS-task half of the
    /// hybrid, and dist modes have no client worlds to rebuild.
    pub fault: FaultPlan,
    /// Membership-epoch cadence in iterations: churn events take effect at
    /// the first boundary at/after their iteration. Sync-SGD jobs use 1
    /// (every iteration is a sync boundary); ESGD jobs use the elastic
    /// sync INTERVAL so reconfiguration rides the existing lazy-sync
    /// schedule.
    pub reconfig_every: u64,
}

impl JobSpec {
    pub fn from_algo(algo: Algo, workers: usize, servers: usize, clients: usize) -> Self {
        Self {
            workers,
            servers,
            clients: if algo.is_mpi() { clients } else { workers },
            ktype: algo.kv_type(),
            server_mode: algo.server_mode(),
            engine_threads: 1,
            collective: AlgoKind::Ring,
            fusion_bytes: 0,
            rings: 2,
            group: 2,
            devices: 1,
            cost: CostParams::testbed1(),
            codec: Codec::identity(),
            topk_ratio: 0.01,
            fault: FaultPlan::none(),
            reconfig_every: 1,
        }
    }

    /// Full wiring from an experiment config, collective layer included:
    /// schedule, fusion cap, ring count, hierarchical group size and the
    /// testbed cost constants the `Auto` autotuner consults. The fault
    /// plan is *not* read here (parsing can fail); callers that want churn
    /// set `spec.fault` from [`ExperimentConfig::fault_plan`].
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        let mut spec = Self::from_algo(cfg.algo, cfg.workers, cfg.servers, cfg.clients);
        spec.collective = cfg.collective_kind();
        spec.fusion_bytes = cfg.fusion_bytes;
        spec.rings = cfg.rings.max(1);
        spec.cost = cfg.cost_params();
        spec.codec = cfg.codec();
        spec.topk_ratio = cfg.topk_ratio;
        spec.group = spec.cost.gpus_per_worker.max(1);
        // cfg.cost_params() already stamps devices into spec.cost; the
        // spec-level copy is what the hub's epoch views hand out.
        spec.devices = cfg.devices.max(1);
        // Membership epochs ride the *strategy's* declared sync cadence
        // (every iteration for sync modes, the lazy INTERVAL for
        // ESGD/Local SGD/BMUF) — the ElasticHub schedule keys off the
        // SyncStrategy trait, not off per-algorithm special cases.
        spec.reconfig_every = cfg.algo.strategy().sync_every(cfg).max(1);
        spec
    }

    /// Pushes per key per sync round: clients for MPI modes (only masters
    /// push), workers for dist modes.
    pub fn expected_pushes(&self) -> usize {
        if self.ktype.is_mpi() {
            self.clients
        } else {
            self.workers
        }
    }
}

// ---------------------------------------------------------------------------
// ElasticHub — epoch-scoped membership coordination
// ---------------------------------------------------------------------------

/// What one worker learns at a membership-epoch boundary: its place in the
/// rebuilt world plus everything needed to renormalize and (re)bootstrap.
#[derive(Debug, Clone)]
pub struct EpochView {
    /// Completed membership epochs after this boundary (plan index + 1).
    pub epoch: u64,
    /// The iteration this boundary rode on.
    pub boundary_iter: u64,
    /// This worker's rank in its client's rebuilt MPI_COMM_WORLD.
    pub mpi_rank: usize,
    pub client_id: usize,
    /// Live members of this worker's client (its new world size).
    pub workers_per_client: usize,
    /// Live workers across all clients (gradient renormalization).
    pub live_workers: usize,
    pub live_clients: usize,
    /// New sync quorum (the hub has already retargeted the servers).
    pub expected_pushes: usize,
    /// This worker's index among all live workers (data resharding).
    pub shard_index: usize,
    /// This client's live ps_ranks ascending — index in this list *is*
    /// the new MPI rank (the rank-translation table).
    pub members: Vec<usize>,
    /// ps_ranks admitted at this boundary (bootstrap coordination).
    pub joined: Vec<usize>,
    /// This worker's cumulative straggle factor (>= 1.0).
    pub straggle: f64,
    /// Devices per worker (k) in the rebuilt world: churn composes with
    /// the device tier — a surviving worker keeps all k device shards, so
    /// views carry the count every renormalization can rely on.
    pub devices: usize,
}

/// A survivor's (or joiner's) barrier result: the view plus its endpoint
/// of the rebuilt per-client world (None for dist-style 1-rank worlds).
pub struct Handout {
    pub view: EpochView,
    pub comm: Option<Comm>,
}

/// One planned membership epoch, fully precomputed at launch: the fault
/// plan is static configuration, so every worker derives the identical
/// boundary schedule and the barrier needs no dynamic discovery.
struct EpochPlan {
    boundary_iter: u64,
    kills: Vec<usize>,
    joins: Vec<usize>,
    /// Survivors whose arrival completes the barrier: (ps_rank, client),
    /// ascending rank. Kills excluded, joiners not yet included.
    survivors: Vec<(usize, usize)>,
    /// Live members after the epoch: (ps_rank, client), ascending rank.
    members_after: Vec<(usize, usize)>,
    /// Cumulative straggle factor per affected rank after this epoch.
    straggle: Vec<(usize, f64)>,
}

struct HubState {
    /// Completed epochs (index of the next planned boundary).
    epoch: usize,
    /// Survivors arrived at the current barrier.
    arrived: BTreeSet<usize>,
    /// Joiners parked and awaiting admission.
    parked: BTreeSet<usize>,
    /// Built handouts awaiting pickup.
    outbox: HashMap<usize, Handout>,
}

/// The launcher's elastic control plane. Workers hit `reconfigure` at each
/// planned boundary (dying ranks simply return instead — fail-stop *at*
/// the boundary, the cloud-preemption model, so no collective ever spans a
/// dead rank); parked joiners are admitted when their epoch builds. The
/// last arrival rebuilds one fresh world per surviving client, updates the
/// scheduler's membership view and retargets the PS sync quorum.
pub struct ElasticHub {
    state: Mutex<HubState>,
    cv: Condvar,
    epochs: Vec<EpochPlan>,
    mpi: bool,
    /// Devices per worker, stamped into every epoch view.
    devices: usize,
    sched: Scheduler,
    /// Control endpoint used to retarget `expected_pushes` (None when the
    /// job runs serverless pure MPI).
    ps_ctl: Option<PsClient>,
}

impl ElasticHub {
    /// Precompute the epoch schedule from a job's fault plan. Fails when
    /// the plan is inconsistent: killing a rank that is not live, or
    /// leaving an epoch with no survivors.
    pub fn new(spec: &JobSpec, sched: Scheduler, ps_ctl: Option<PsClient>) -> Result<Self> {
        ensure!(
            spec.clients >= 1,
            "elastic job needs at least 1 client, got clients={}",
            spec.clients
        );
        ensure!(
            spec.workers % spec.clients == 0,
            "workers must divide evenly into clients: workers={} clients={}",
            spec.workers,
            spec.clients
        );
        let wpc = spec.workers / spec.clients;
        let cadence = spec.reconfig_every.max(1);
        // Live set evolves as we walk the plan.
        let mut live: BTreeMap<usize, usize> =
            (0..spec.workers).map(|r| (r, r / wpc)).collect();
        let mut straggle: BTreeMap<usize, f64> = BTreeMap::new();
        let mut next_join_rank = spec.workers;

        // Group events by their effective boundary iteration.
        let mut grouped: BTreeMap<u64, Vec<FaultKind>> = BTreeMap::new();
        for ev in &spec.fault.events {
            let boundary = (ev.at_iter + cadence) / cadence * cadence - 1;
            grouped.entry(boundary).or_default().push(ev.kind);
        }

        let mut epochs = Vec::new();
        for (boundary_iter, kinds) in grouped {
            let mut kills = Vec::new();
            let mut joins = Vec::new();
            for kind in &kinds {
                match *kind {
                    FaultKind::Kill { rank } => {
                        ensure!(
                            live.contains_key(&rank),
                            "fault plan kills rank {rank} at iter {boundary_iter}, \
                             but it is not live there"
                        );
                        live.remove(&rank);
                        kills.push(rank);
                    }
                    FaultKind::Straggle { rank, factor } => {
                        ensure!(
                            live.contains_key(&rank),
                            "fault plan straggles rank {rank} at iter {boundary_iter}, \
                             but it is not live there"
                        );
                        *straggle.entry(rank).or_insert(1.0) *= factor;
                    }
                    FaultKind::Join { .. } => {}
                }
            }
            let survivors: Vec<(usize, usize)> =
                live.iter().map(|(&r, &c)| (r, c)).collect();
            ensure!(
                !survivors.is_empty(),
                "fault plan leaves no survivors at iter {boundary_iter}"
            );
            ensure!(
                survivors.iter().any(|&(_, c)| c == 0),
                "fault plan empties client 0 at iter {boundary_iter}: client 0 \
                 carries the validation records on both trainer planes"
            );
            // Joins admitted after kills: a joiner lands on the *post-kill*
            // emptiest client (or its explicit hint).
            for kind in &kinds {
                if let FaultKind::Join { client } = *kind {
                    let target = client.unwrap_or_else(|| {
                        let mut counts: BTreeMap<usize, usize> =
                            (0..spec.clients).map(|c| (c, 0)).collect();
                        for &c in live.values() {
                            *counts.entry(c).or_insert(0) += 1;
                        }
                        counts
                            .iter()
                            .min_by_key(|&(&c, &n)| (n, c))
                            .map(|(&c, _)| c)
                            .unwrap_or(0)
                    });
                    ensure!(
                        target < spec.clients,
                        "fault plan joins client {target}, but the job has \
                         {} clients",
                        spec.clients
                    );
                    live.insert(next_join_rank, target);
                    joins.push(next_join_rank);
                    next_join_rank += 1;
                }
            }
            let members_after: Vec<(usize, usize)> =
                live.iter().map(|(&r, &c)| (r, c)).collect();
            epochs.push(EpochPlan {
                boundary_iter,
                kills,
                joins,
                survivors,
                members_after,
                straggle: straggle.iter().map(|(&r, &f)| (r, f)).collect(),
            });
        }
        Ok(Self {
            state: Mutex::new(HubState {
                epoch: 0,
                arrived: BTreeSet::new(),
                parked: BTreeSet::new(),
                outbox: HashMap::new(),
            }),
            cv: Condvar::new(),
            epochs,
            mpi: spec.ktype.is_mpi(),
            devices: spec.devices.max(1),
            sched,
            ps_ctl,
        })
    }

    /// The boundary iteration of the next planned epoch after
    /// `epochs_done` completed ones (None when the plan is exhausted).
    pub fn boundary_iter(&self, epochs_done: u64) -> Option<u64> {
        self.epochs.get(epochs_done as usize).map(|e| e.boundary_iter)
    }

    /// Ranks that leave at the next boundary.
    pub fn dying_at(&self, epochs_done: u64) -> &[usize] {
        self.epochs
            .get(epochs_done as usize)
            .map(|e| e.kills.as_slice())
            .unwrap_or(&[])
    }

    /// The checkpoint master of `client` at the next boundary: its lowest
    /// *surviving* ps_rank (None when the whole client dies).
    pub fn ckpt_master(&self, epochs_done: u64, client: usize) -> Option<usize> {
        self.epochs.get(epochs_done as usize).and_then(|e| {
            e.survivors
                .iter()
                .find(|&&(_, c)| c == client)
                .map(|&(r, _)| r)
        })
    }

    /// (ps_rank, client, admission epoch index) of every planned joiner —
    /// the launcher pre-spawns one worker thread per entry.
    pub fn joiner_seeds(&self) -> Vec<(usize, usize, u64)> {
        let mut seeds = Vec::new();
        for (k, e) in self.epochs.iter().enumerate() {
            for &rank in &e.joins {
                let client = e
                    .members_after
                    .iter()
                    .find(|&&(r, _)| r == rank)
                    .map(|&(_, c)| c)
                    .expect("joiner in members_after");
                seeds.push((rank, client, k as u64));
            }
        }
        seeds
    }

    pub fn n_epochs(&self) -> usize {
        self.epochs.len()
    }

    /// Live members (ps_rank, client) after planned epoch `epoch_idx`
    /// completes — the sim plane rebuilds its membership tables from this
    /// so both planes share one boundary schedule.
    pub fn members_after(&self, epoch_idx: u64) -> &[(usize, usize)] {
        self.epochs
            .get(epoch_idx as usize)
            .map(|e| e.members_after.as_slice())
            .unwrap_or(&[])
    }

    /// Ranks admitted at planned epoch `epoch_idx`.
    pub fn joins_at(&self, epoch_idx: u64) -> &[usize] {
        self.epochs
            .get(epoch_idx as usize)
            .map(|e| e.joins.as_slice())
            .unwrap_or(&[])
    }

    /// Cumulative straggle factor of `rank` after planned epoch
    /// `epoch_idx` completes (1.0 when unaffected).
    pub fn straggle_after(&self, epoch_idx: u64, rank: usize) -> f64 {
        self.epochs
            .get(epoch_idx as usize)
            .and_then(|e| {
                e.straggle
                    .iter()
                    .find(|&&(r, _)| r == rank)
                    .map(|&(_, f)| f)
            })
            .unwrap_or(1.0)
    }

    /// Survivor barrier: blocks until every survivor of the current epoch
    /// arrived and every due joiner parked, then hands each member its
    /// place in the rebuilt world. Dying ranks must NOT call this — they
    /// return from their worker instead (their departure is part of the
    /// precomputed plan, so the barrier never waits on them).
    pub fn reconfigure(&self, ps_rank: usize) -> Handout {
        let mut st = self.state.lock().unwrap();
        assert!(
            st.epoch < self.epochs.len(),
            "reconfigure past the last planned epoch"
        );
        st.arrived.insert(ps_rank);
        self.try_build(&mut st);
        loop {
            if let Some(h) = st.outbox.remove(&ps_rank) {
                return h;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Joiner entry point: parks until this rank's admission epoch builds,
    /// then returns its place in the world it joined.
    pub fn await_join(&self, ps_rank: usize) -> Handout {
        let mut st = self.state.lock().unwrap();
        st.parked.insert(ps_rank);
        self.try_build(&mut st);
        loop {
            if let Some(h) = st.outbox.remove(&ps_rank) {
                return h;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Build the current epoch if its barrier is complete: one fresh world
    /// per surviving client, scheduler view published, PS quorum
    /// retargeted, handouts for every member.
    fn try_build(&self, st: &mut HubState) {
        let Some(plan) = self.epochs.get(st.epoch) else { return };
        if !plan.survivors.iter().all(|&(r, _)| st.arrived.contains(&r)) {
            return;
        }
        if !plan.joins.iter().all(|r| st.parked.contains(r)) {
            return;
        }
        // Membership authority bookkeeping (the scheduler owns the view).
        for &dead in &plan.kills {
            self.sched.deregister(dead);
        }
        for &j in &plan.joins {
            self.sched.admit(j);
            st.parked.remove(&j);
        }
        self.sched.publish_view();

        let mut per_client: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for &(r, c) in &plan.members_after {
            per_client.entry(c).or_default().push(r);
        }
        let live_workers = plan.members_after.len();
        let live_clients = per_client.len();
        let expected_pushes = if self.mpi { live_clients } else { live_workers };
        if let Some(ps) = &self.ps_ctl {
            ps.set_expected_pushes(expected_pushes);
        }
        let shard_index = |rank: usize| {
            plan.members_after
                .iter()
                .position(|&(r, _)| r == rank)
                .expect("member")
        };
        let straggle_of = |rank: usize| {
            plan.straggle
                .iter()
                .find(|&&(r, _)| r == rank)
                .map(|&(_, f)| f)
                .unwrap_or(1.0)
        };
        let epoch = st.epoch as u64 + 1;
        for (&client_id, members) in &per_client {
            let comms: Vec<Option<Comm>> = if self.mpi {
                World::create(members.len()).into_iter().map(Some).collect()
            } else {
                members.iter().map(|_| None).collect()
            };
            for ((mpi_rank, &rank), comm) in members.iter().enumerate().zip(comms) {
                let view = EpochView {
                    epoch,
                    boundary_iter: plan.boundary_iter,
                    mpi_rank,
                    client_id,
                    workers_per_client: members.len(),
                    live_workers,
                    live_clients,
                    expected_pushes,
                    shard_index: shard_index(rank),
                    members: members.clone(),
                    joined: plan.joins.clone(),
                    straggle: straggle_of(rank),
                    devices: self.devices,
                };
                st.outbox.insert(rank, Handout { view, comm });
            }
        }
        st.epoch += 1;
        st.arrived.clear();
        self.cv.notify_all();
    }
}

/// The per-thread clone set of a job's kvstore wiring — one place to add
/// a knob so original workers and pre-spawned joiners can never diverge.
#[derive(Clone)]
struct Wiring {
    ktype: KvType,
    engine_threads: usize,
    workers: usize,
    clients: usize,
    collective: AlgoKind,
    fusion_bytes: usize,
    rings: usize,
    group: usize,
    cost: CostParams,
    codec: Codec,
    topk_ratio: f64,
}

impl Wiring {
    fn from_spec(spec: &JobSpec) -> Self {
        Self {
            ktype: spec.ktype,
            engine_threads: spec.engine_threads,
            workers: spec.workers,
            clients: spec.clients,
            collective: spec.collective,
            fusion_bytes: spec.fusion_bytes,
            rings: spec.rings,
            group: spec.group,
            cost: spec.cost.clone(),
            codec: spec.codec,
            topk_ratio: spec.topk_ratio,
        }
    }

    /// Build a worker's engine + configured KVStore endpoint.
    fn make_kv(&self, comm: Option<Comm>, ps: Option<PsClient>) -> (Arc<Engine>, KvWorker) {
        let engine = Arc::new(Engine::new(self.engine_threads));
        let mut kv = KvWorker::create(self.ktype, engine.clone(), comm, ps);
        kv.configure_collective(
            self.collective,
            self.rings,
            self.group,
            self.fusion_bytes,
            self.cost.clone(),
        );
        kv.configure_compression(self.codec, self.topk_ratio);
        (engine, kv)
    }
}

/// Everything a worker thread receives from the launcher.
pub struct WorkerCtx {
    /// Rank in the PS namespace (0..workers).
    pub ps_rank: usize,
    /// Which MPI client (job) this worker belongs to.
    pub client_id: usize,
    /// Rank within the client's MPI_COMM_WORLD.
    pub mpi_rank: usize,
    pub workers_per_client: usize,
    pub n_workers: usize,
    pub n_clients: usize,
    /// The wired KVStore endpoint (owns comm + PS client).
    pub kv: KvWorker,
    pub engine: Arc<Engine>,
    /// Elastic control plane (None on static jobs): workers consult it for
    /// membership-epoch boundaries and rebuilt worlds.
    pub hub: Option<Arc<ElasticHub>>,
    /// Set for late joiners: the admission view (start iteration =
    /// `boundary_iter + 1`, membership, bootstrap coordinates).
    pub join_view: Option<EpochView>,
}

/// Launch a job and run `worker_fn` on every worker thread; returns each
/// worker's result (indexed by PS rank; planned joiners follow the launch
/// population). Servers/scheduler shut down after all workers finish.
///
/// With a non-empty `spec.fault` the job is *elastic*: an [`ElasticHub`]
/// is wired into every [`WorkerCtx`] and one extra worker thread is
/// pre-spawned per planned join, parked until its admission epoch.
///
/// Errors on an inconsistent spec or fault plan ([`ElasticHub::new`]'s
/// diagnostics — which name the offending rank and iteration — propagate
/// verbatim).
pub fn launch<F, R>(spec: &JobSpec, worker_fn: F) -> Result<Vec<R>>
where
    F: Fn(WorkerCtx) -> R + Clone + Send + 'static,
    R: Send + 'static,
{
    // One-job-per-process: the job owns its private scheduler, exactly as
    // before the cluster authority existed.
    launch_with(spec, worker_fn, Scheduler::new(spec.workers, spec.servers))
}

/// [`launch`] against a caller-supplied [`Scheduler`] — the seam the
/// cluster authority uses to run several jobs against per-job quorums
/// registered on one [`crate::ps::ClusterScheduler`]. A plain [`launch`]
/// is exactly `launch_with(spec, f, Scheduler::new(workers, servers))`,
/// so a cluster running one job takes the identical code path.
pub fn launch_with<F, R>(spec: &JobSpec, worker_fn: F, scheduler: Scheduler) -> Result<Vec<R>>
where
    F: Fn(WorkerCtx) -> R + Clone + Send + 'static,
    R: Send + 'static,
{
    ensure!(spec.workers >= 1, "job needs at least 1 worker");
    ensure!(
        spec.clients >= 1 && spec.clients <= spec.workers,
        "clients must be in 1..=workers: workers={} clients={}",
        spec.workers,
        spec.clients
    );
    ensure!(
        spec.workers % spec.clients == 0,
        "workers must divide evenly into clients: workers={} clients={}",
        spec.workers,
        spec.clients
    );
    ensure!(
        spec.fault.is_empty() || spec.ktype.is_mpi(),
        "fault plans require an MPI kvstore type: elasticity is the \
         PS+MPI hybrid's story, dist modes have no client worlds to rebuild"
    );
    let wpc = spec.workers / spec.clients;

    // 2. PS servers (skipped entirely for pure-MPI jobs).
    let servers = if spec.servers > 0 {
        let group = ServerGroup::spawn(spec.servers, spec.server_mode, spec.expected_pushes());
        // Register server tasks with the scheduler (they run on their own
        // threads already; registration is what unblocks the job).
        for _ in 0..spec.servers {
            let s = scheduler.handle();
            std::thread::spawn(move || s.register(Role::Server));
        }
        Some(group)
    } else {
        None
    };

    // 2b. Elastic control plane (only when the plan scripts churn). A bad
    // plan surfaces the hub's own diagnostic (rank + iteration) verbatim.
    let hub: Option<Arc<ElasticHub>> = if spec.fault.is_empty() {
        None
    } else {
        match ElasticHub::new(
            spec,
            scheduler.handle(),
            servers.as_ref().map(|g| g.client()),
        ) {
            Ok(hub) => Some(Arc::new(hub)),
            Err(e) => {
                if let Some(group) = servers {
                    group.shutdown();
                }
                return Err(e.context("invalid fault plan for this job"));
            }
        }
    };

    // 3. One MPI_COMM_WORLD per client (each client is a separate mpirun
    // job in the paper); dist modes get single-rank worlds.
    let mut handles = Vec::with_capacity(spec.workers);
    for client_id in 0..spec.clients {
        let comms: Vec<Comm> = if spec.ktype.is_mpi() {
            World::create(wpc)
        } else {
            // Dist modes: no MPI; workers are standalone.
            (0..wpc).flat_map(|_| World::create(1)).collect()
        };
        for (mpi_rank, comm) in comms.into_iter().enumerate() {
            let ps_rank = client_id * wpc + mpi_rank;
            let ps_client: Option<PsClient> = servers.as_ref().map(|g| g.client());
            let sched = scheduler.handle();
            let f = worker_fn.clone();
            let wiring = Wiring::from_spec(spec);
            let hub = hub.clone();
            handles.push(std::thread::Builder::new()
                .name(format!("worker-{ps_rank}"))
                .spawn(move || {
                    // Register under the launcher-assigned rank so the
                    // scheduler's live set speaks ps_ranks.
                    sched.register_as(ps_rank);
                    let comm_opt = if wiring.ktype.is_mpi() { Some(comm) } else { None };
                    let (engine, kv) = wiring.make_kv(comm_opt, ps_client);
                    let ctx = WorkerCtx {
                        ps_rank,
                        client_id,
                        mpi_rank,
                        workers_per_client: wpc,
                        n_workers: wiring.workers,
                        n_clients: wiring.clients,
                        kv,
                        engine,
                        hub,
                        join_view: None,
                    };
                    (ps_rank, f(ctx))
                })
                .expect("spawn worker"));
        }
    }

    // 3b. Pre-spawn planned joiners: each parks on the hub until its
    // admission epoch, then enters `worker_fn` with a wired kvstore on the
    // world it joined.
    if let Some(hub) = &hub {
        for (ps_rank, client_id, _epoch) in hub.joiner_seeds() {
            let hub = hub.clone();
            let ps_client: Option<PsClient> = servers.as_ref().map(|g| g.client());
            let f = worker_fn.clone();
            let wiring = Wiring::from_spec(spec);
            handles.push(std::thread::Builder::new()
                .name(format!("worker-{ps_rank}-joiner"))
                .spawn(move || {
                    let handout = hub.await_join(ps_rank);
                    let (engine, kv) = wiring.make_kv(handout.comm, ps_client);
                    let view = handout.view;
                    let ctx = WorkerCtx {
                        ps_rank,
                        client_id,
                        mpi_rank: view.mpi_rank,
                        workers_per_client: view.workers_per_client,
                        n_workers: wiring.workers,
                        n_clients: wiring.clients,
                        kv,
                        engine,
                        hub: Some(hub),
                        join_view: Some(view),
                    };
                    (ps_rank, f(ctx))
                })
                .expect("spawn joiner"));
        }
    }

    let mut results: Vec<(usize, R)> = handles
        .into_iter()
        .map(|h| h.join().expect("worker panicked"))
        .collect();
    results.sort_by_key(|(rank, _)| *rank);

    if let Some(group) = servers {
        group.shutdown();
    }
    Ok(results.into_iter().map(|(_, r)| r).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pure-MPI sync spec used across these tests.
    fn mpi_spec(workers: usize, clients: usize) -> JobSpec {
        JobSpec {
            workers,
            servers: 0,
            clients,
            ktype: KvType::SyncMpi,
            server_mode: SyncMode::Sync,
            engine_threads: 1,
            collective: AlgoKind::Ring,
            fusion_bytes: 0,
            rings: 2,
            group: 2,
            devices: 1,
            cost: CostParams::testbed1(),
            codec: Codec::identity(),
            topk_ratio: 0.01,
            fault: FaultPlan::none(),
            reconfig_every: 1,
        }
    }

    #[test]
    fn launch_pure_mpi_job_allreduces() {
        let spec = mpi_spec(4, 1);
        let out = launch(&spec, |ctx| {
            let v = ctx.kv.pushpull(0, vec![1.0, (ctx.ps_rank + 1) as f32]).wait();
            v
        })
        .unwrap();
        assert_eq!(out.len(), 4);
        for v in out {
            assert_eq!(v, vec![4.0, 10.0]);
        }
    }

    #[test]
    fn launch_two_clients_have_separate_worlds() {
        let spec = mpi_spec(4, 2);
        let out = launch(&spec, |ctx| {
            let v = ctx.kv.pushpull(0, vec![1.0]).wait();
            (ctx.client_id, ctx.mpi_rank, v[0])
        })
        .unwrap();
        // Each client has 2 workers: allreduce sums within the client only.
        for (client, rank, sum) in out {
            assert!(client < 2 && rank < 2);
            assert_eq!(sum, 2.0);
        }
    }

    #[test]
    fn launch_dist_job_with_servers() {
        let spec = JobSpec::from_algo(Algo::named("dist-SGD"), 3, 2, 3);
        assert_eq!(spec.expected_pushes(), 3);
        let out = launch(&spec, |ctx| {
            if ctx.ps_rank == 0 {
                ctx.kv.init(0, vec![0.0], true);
                ctx.kv.set_optimizer(|| {
                    Box::new(crate::optimizer::Sgd::new(
                        crate::optimizer::SgdHyper::plain(1.0, 1.0),
                    ))
                });
            }
            ctx.kv.push(0, vec![1.0]);
            ctx.kv.pull(0).wait()[0]
        })
        .unwrap();
        for v in out {
            assert_eq!(v, -3.0);
        }
    }

    #[test]
    fn mpi_job_with_servers_masters_push() {
        let spec = JobSpec::from_algo(Algo::named("mpi-SGD"), 4, 1, 2);
        assert_eq!(spec.expected_pushes(), 2);
        let out = launch(&spec, |ctx| {
            if ctx.ps_rank == 0 {
                ctx.kv.init(0, vec![0.0], true);
                ctx.kv.set_optimizer(|| {
                    Box::new(crate::optimizer::Sgd::new(
                        crate::optimizer::SgdHyper::plain(1.0, 1.0),
                    ))
                });
            }
            ctx.kv.push(0, vec![1.0]);
            ctx.kv.pull(0).wait()[0]
        })
        .unwrap();
        // 2 clients x client-sum 2.0 => server applies w = 0 - 4.
        for v in out {
            assert_eq!(v, -4.0);
        }
    }

    #[test]
    fn uneven_clients_rejected() {
        let spec = mpi_spec(5, 2);
        let err = launch(&spec, |_| ()).unwrap_err().to_string();
        assert!(
            err.contains("divide evenly") && err.contains("workers=5") && err.contains("clients=2"),
            "error must name both values: {err}"
        );
    }

    #[test]
    fn hub_rejects_non_divisible_workers_clients() {
        let mut spec = mpi_spec(5, 2);
        spec.fault = FaultPlan::parse("kill:1@0").unwrap();
        let err = ElasticHub::new(&spec, Scheduler::new(0, 0), None)
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("workers=5") && err.contains("clients=2"),
            "error must name both values: {err}"
        );
    }

    // -- elasticity ---------------------------------------------------------

    /// Drive a worker through the elastic boundary protocol: allreduce
    /// once per iteration, reconfigure at planned boundaries, die when the
    /// plan says so. Returns (iterations run, final allreduce sum).
    fn elastic_worker(ctx: WorkerCtx, total_iters: u64) -> (u64, f32) {
        let hub = ctx.hub.as_ref().expect("elastic job");
        let mut epochs_done = ctx.join_view.as_ref().map_or(0, |v| v.epoch);
        let mut iter = ctx.join_view.as_ref().map_or(0, |v| v.boundary_iter + 1);
        let mut ran = 0;
        let mut last = 0.0;
        while iter < total_iters {
            last = ctx.kv.pushpull(0, vec![1.0]).wait()[0];
            ran += 1;
            if hub.boundary_iter(epochs_done) == Some(iter) {
                ctx.kv.wait_all();
                if hub.dying_at(epochs_done).contains(&ctx.ps_rank) {
                    return (ran, last);
                }
                let handout = hub.reconfigure(ctx.ps_rank);
                epochs_done = handout.view.epoch;
                if let Some(comm) = handout.comm {
                    drop(ctx.kv.replace_comm(comm));
                }
            }
            iter += 1;
        }
        (ran, last)
    }

    #[test]
    fn elastic_kill_reconfigures_without_deadlock() {
        // 4 ranks, rank 3 dies at iter 1: survivors' allreduce world
        // shrinks from 4 to 3 and keeps completing (the static launcher
        // would deadlock waiting on the dead rank forever).
        let mut spec = mpi_spec(4, 1);
        spec.fault = FaultPlan::parse("kill:3@1").unwrap();
        let out = launch(&spec, |ctx| elastic_worker(ctx, 4)).unwrap();
        assert_eq!(out.len(), 4);
        for (rank, (ran, last)) in out.iter().enumerate() {
            if rank == 3 {
                assert_eq!(*ran, 2); // died at the iter-1 boundary
                assert_eq!(*last, 4.0);
            } else {
                assert_eq!(*ran, 4);
                assert_eq!(*last, 3.0, "post-shrink world sums 3 ranks");
            }
        }
    }

    #[test]
    fn elastic_join_grows_the_world() {
        // 2 ranks + a joiner at iter 1: iterations 2..4 sum over 3 ranks.
        let mut spec = mpi_spec(2, 1);
        spec.fault = FaultPlan::parse("join@1").unwrap();
        let out = launch(&spec, |ctx| elastic_worker(ctx, 4)).unwrap();
        assert_eq!(out.len(), 3);
        for (rank, (ran, last)) in out.iter().enumerate() {
            if rank == 2 {
                assert_eq!(*ran, 2, "joiner runs iterations 2 and 3");
            } else {
                assert_eq!(*ran, 4);
            }
            assert_eq!(*last, 3.0, "rank {rank}");
        }
    }

    #[test]
    fn elastic_kill_and_join_across_two_clients() {
        // 4 ranks in 2 clients; client 0 loses rank 1, the joiner lands on
        // the now-emptiest client 0. Client worlds stay 2-rank throughout
        // for client 1; client 0 goes 2 -> 1 -> 2.
        let mut spec = mpi_spec(4, 2);
        spec.fault = FaultPlan::parse("kill:1@0,join@1").unwrap();
        let out = launch(&spec, |ctx| elastic_worker(ctx, 4)).unwrap();
        assert_eq!(out.len(), 5);
        let (ran1, _) = out[1];
        assert_eq!(ran1, 1); // killed at the iter-0 boundary
        let (ran4, last4) = out[4];
        assert_eq!(ran4, 2);
        assert_eq!(last4, 2.0, "client 0 back to 2 ranks");
        let (ran0, last0) = out[0];
        assert_eq!(ran0, 4);
        assert_eq!(last0, 2.0);
        let (ran2, last2) = out[2];
        assert_eq!(ran2, 4);
        assert_eq!(last2, 2.0, "client 1 untouched");
    }

    #[test]
    fn elastic_hub_updates_scheduler_views_and_quorum() {
        // With servers: the killed rank's missing push must not wedge the
        // sync round after reconfiguration (quorum retargeted to the live
        // client count = 1 client here throughout).
        let mut spec = mpi_spec(3, 1);
        spec.servers = 1;
        spec.fault = FaultPlan::parse("kill:2@0").unwrap();
        let out = launch(&spec, |ctx| {
            let hub = ctx.hub.clone().expect("elastic");
            if ctx.ps_rank == 0 {
                ctx.kv.init(0, vec![0.0], true);
                ctx.kv.set_optimizer(|| {
                    Box::new(crate::optimizer::Sgd::new(
                        crate::optimizer::SgdHyper::plain(1.0, 1.0),
                    ))
                });
            }
            // Iter 0: all 3 push (client aggregate 3.0), pull.
            ctx.kv.push(0, vec![1.0]);
            let v0 = ctx.kv.pull(0).wait()[0];
            ctx.kv.wait_all();
            if hub.dying_at(0).contains(&ctx.ps_rank) {
                return (v0, f32::NAN);
            }
            let handout = hub.reconfigure(ctx.ps_rank);
            if let Some(comm) = handout.comm {
                drop(ctx.kv.replace_comm(comm));
            }
            // Iter 1: the 2 survivors push (aggregate 2.0), pull.
            ctx.kv.push(0, vec![1.0]);
            (v0, ctx.kv.pull(0).wait()[0])
        })
        .unwrap();
        assert_eq!(out[0].0, -3.0);
        assert_eq!(out[1].0, -3.0);
        assert!(out[2].1.is_nan());
        assert_eq!(out[0].1, -5.0, "post-shrink round applies 2 pushes");
        assert_eq!(out[1].1, -5.0);
    }

    #[test]
    fn fault_plan_on_dist_mode_rejected() {
        let mut spec = JobSpec::from_algo(Algo::named("dist-SGD"), 2, 1, 2);
        spec.fault = FaultPlan::parse("kill:1@0").unwrap();
        let err = launch(&spec, |_| ()).unwrap_err().to_string();
        assert!(err.contains("MPI kvstore type"), "got: {err}");
    }

    #[test]
    fn launch_propagates_hub_diagnostic_with_rank_and_iteration() {
        // Killing a never-live rank: the surfaced error must carry the
        // hub's own diagnostic, not a detail-free launcher panic.
        let mut spec = mpi_spec(2, 1);
        spec.fault = FaultPlan::parse("kill:7@3").unwrap();
        let err = format!("{:#}", launch(&spec, |_| ()).unwrap_err());
        assert!(
            err.contains("kills rank 7") && err.contains("iter 3"),
            "error must name the rank and iteration: {err}"
        );
    }

    #[test]
    fn hub_plan_precomputation_is_consistent() {
        let mut spec = mpi_spec(4, 2);
        spec.reconfig_every = 8;
        spec.fault = FaultPlan::parse("kill:1@3,straggle:0@3x2,join@9").unwrap();
        let sched = Scheduler::new(0, 0);
        let hub = ElasticHub::new(&spec, sched, None).unwrap();
        // Events at iters 3 (boundary 7) and 9 (boundary 15): two epochs.
        assert_eq!(hub.n_epochs(), 2);
        assert_eq!(hub.boundary_iter(0), Some(7));
        assert_eq!(hub.boundary_iter(1), Some(15));
        assert_eq!(hub.boundary_iter(2), None);
        assert_eq!(hub.dying_at(0), [1usize].as_slice());
        assert!(hub.dying_at(1).is_empty());
        // Client 0's checkpoint master at epoch 0 is rank 0 (1 dies).
        assert_eq!(hub.ckpt_master(0, 0), Some(0));
        assert_eq!(hub.ckpt_master(0, 1), Some(2));
        // The joiner (rank 4) lands on client 0 (1 member vs 2) at epoch 1.
        assert_eq!(hub.joiner_seeds(), vec![(4, 0, 1)]);
        // Kill at a boundary with no live target fails fast.
        spec.fault = FaultPlan::parse("kill:9@0").unwrap();
        let sched = Scheduler::new(0, 0);
        assert!(ElasticHub::new(&spec, sched, None).is_err());
    }
}
