//! Job launcher — the LSF/`bsub` substitution (§4.1.2).
//!
//! The paper's launcher runs on the cluster front end: it starts the MXNET
//! scheduler first, broadcasts its address, then submits each MPI client as
//! a separate `mpirun` job, with `#servers` tunable down to zero for pure
//! MPI. This launcher does the same with threads: scheduler, PS server
//! group, then one [`World`](crate::mpisim::World) per client whose worker
//! threads each get a fully wired [`WorkerCtx`] (PS rank, client id, MPI
//! communicator, KVStore endpoint).

use crate::collectives::AlgoKind;
use crate::config::{Algo, ExperimentConfig};
use crate::engine::Engine;
use crate::kvstore::{KvType, KvWorker};
use crate::mpisim::{Comm, World};
use crate::netsim::CostParams;
use crate::ps::{PsClient, Role, Scheduler, ServerGroup, SyncMode};
use std::sync::Arc;

/// Shape of a job: the launcher's CLI parameters (§4.1.2).
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub workers: usize,
    pub servers: usize,
    pub clients: usize,
    pub ktype: KvType,
    pub server_mode: SyncMode,
    /// Engine threads per worker.
    pub engine_threads: usize,
    /// Intra-client allreduce schedule (the `collective` config knob).
    pub collective: AlgoKind,
    /// Gradient-fusion bucket cap in bytes (0 disables).
    pub fusion_bytes: usize,
    /// Rings for the multi-ring tensor allreduce (§6.3.2).
    pub rings: usize,
    /// Group size for the hierarchical schedule.
    pub group: usize,
    /// Cost-model constants the `Auto` schedule tunes against.
    pub cost: CostParams,
}

impl JobSpec {
    pub fn from_algo(algo: Algo, workers: usize, servers: usize, clients: usize) -> Self {
        Self {
            workers,
            servers,
            clients: if algo.is_mpi() { clients } else { workers },
            ktype: algo.kv_type(),
            server_mode: algo.server_mode(),
            engine_threads: 1,
            collective: AlgoKind::Ring,
            fusion_bytes: 0,
            rings: 2,
            group: 2,
            cost: CostParams::testbed1(),
        }
    }

    /// Full wiring from an experiment config, collective layer included:
    /// schedule, fusion cap, ring count, hierarchical group size and the
    /// testbed cost constants the `Auto` autotuner consults.
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        let mut spec = Self::from_algo(cfg.algo, cfg.workers, cfg.servers, cfg.clients);
        spec.collective = cfg.collective_kind();
        spec.fusion_bytes = cfg.fusion_bytes;
        spec.rings = cfg.rings.max(1);
        spec.cost = cfg.cost_params();
        spec.group = spec.cost.gpus_per_worker.max(1);
        spec
    }

    /// Pushes per key per sync round: clients for MPI modes (only masters
    /// push), workers for dist modes.
    pub fn expected_pushes(&self) -> usize {
        if self.ktype.is_mpi() {
            self.clients
        } else {
            self.workers
        }
    }
}

/// Everything a worker thread receives from the launcher.
pub struct WorkerCtx {
    /// Rank in the PS namespace (0..workers).
    pub ps_rank: usize,
    /// Which MPI client (job) this worker belongs to.
    pub client_id: usize,
    /// Rank within the client's MPI_COMM_WORLD.
    pub mpi_rank: usize,
    pub workers_per_client: usize,
    pub n_workers: usize,
    pub n_clients: usize,
    /// The wired KVStore endpoint (owns comm + PS client).
    pub kv: KvWorker,
    pub engine: Arc<Engine>,
}

/// Launch a job and run `worker_fn` on every worker thread; returns each
/// worker's result (indexed by PS rank). Servers/scheduler shut down after
/// all workers finish.
pub fn launch<F, R>(spec: &JobSpec, worker_fn: F) -> Vec<R>
where
    F: Fn(WorkerCtx) -> R + Clone + Send + 'static,
    R: Send + 'static,
{
    assert!(spec.workers >= 1);
    assert!(spec.clients >= 1 && spec.clients <= spec.workers);
    assert_eq!(
        spec.workers % spec.clients,
        0,
        "workers must divide evenly into clients"
    );
    let wpc = spec.workers / spec.clients;

    // 1. Scheduler first (§4.1.2): it must be up before anyone connects.
    let scheduler = Scheduler::new(spec.workers, spec.servers);

    // 2. PS servers (skipped entirely for pure-MPI jobs).
    let servers = if spec.servers > 0 {
        let group = ServerGroup::spawn(spec.servers, spec.server_mode, spec.expected_pushes());
        // Register server tasks with the scheduler (they run on their own
        // threads already; registration is what unblocks the job).
        for _ in 0..spec.servers {
            let s = scheduler.handle();
            std::thread::spawn(move || s.register(Role::Server));
        }
        Some(group)
    } else {
        None
    };

    // 3. One MPI_COMM_WORLD per client (each client is a separate mpirun
    // job in the paper); dist modes get single-rank worlds.
    let mut handles = Vec::with_capacity(spec.workers);
    for client_id in 0..spec.clients {
        let comms: Vec<Comm> = if spec.ktype.is_mpi() {
            World::create(wpc)
        } else {
            // Dist modes: no MPI; workers are standalone.
            (0..wpc).flat_map(|_| World::create(1)).collect()
        };
        for (mpi_rank, comm) in comms.into_iter().enumerate() {
            let ps_rank = client_id * wpc + mpi_rank;
            let ps_client: Option<PsClient> = servers.as_ref().map(|g| g.client());
            let sched = scheduler.handle();
            let f = worker_fn.clone();
            let ktype = spec.ktype;
            let engine_threads = spec.engine_threads;
            let (workers, clients) = (spec.workers, spec.clients);
            let (collective, fusion_bytes) = (spec.collective, spec.fusion_bytes);
            let (rings, group, cost) = (spec.rings, spec.group, spec.cost.clone());
            handles.push(std::thread::Builder::new()
                .name(format!("worker-{ps_rank}"))
                .spawn(move || {
                    sched.register(Role::Worker);
                    let engine = Arc::new(Engine::new(engine_threads));
                    let comm_opt = if ktype.is_mpi() { Some(comm) } else { None };
                    let mut kv = KvWorker::create(ktype, engine.clone(), comm_opt, ps_client);
                    kv.configure_collective(collective, rings, group, fusion_bytes, cost);
                    let ctx = WorkerCtx {
                        ps_rank,
                        client_id,
                        mpi_rank,
                        workers_per_client: wpc,
                        n_workers: workers,
                        n_clients: clients,
                        kv,
                        engine,
                    };
                    (ps_rank, f(ctx))
                })
                .expect("spawn worker"));
        }
    }

    let mut results: Vec<(usize, R)> = handles
        .into_iter()
        .map(|h| h.join().expect("worker panicked"))
        .collect();
    results.sort_by_key(|(rank, _)| *rank);

    if let Some(group) = servers {
        group.shutdown();
    }
    results.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_pure_mpi_job_allreduces() {
        let spec = JobSpec {
            workers: 4,
            servers: 0,
            clients: 1,
            ktype: KvType::SyncMpi,
            server_mode: SyncMode::Sync,
            engine_threads: 1,
            collective: AlgoKind::Ring,
            fusion_bytes: 0,
            rings: 2,
            group: 2,
            cost: CostParams::testbed1(),
        };
        let out = launch(&spec, |ctx| {
            let v = ctx.kv.pushpull(0, vec![1.0, (ctx.ps_rank + 1) as f32]).wait();
            v
        });
        assert_eq!(out.len(), 4);
        for v in out {
            assert_eq!(v, vec![4.0, 10.0]);
        }
    }

    #[test]
    fn launch_two_clients_have_separate_worlds() {
        let spec = JobSpec {
            workers: 4,
            servers: 0,
            clients: 2,
            ktype: KvType::SyncMpi,
            server_mode: SyncMode::Sync,
            engine_threads: 1,
            collective: AlgoKind::Ring,
            fusion_bytes: 0,
            rings: 2,
            group: 2,
            cost: CostParams::testbed1(),
        };
        let out = launch(&spec, |ctx| {
            let v = ctx.kv.pushpull(0, vec![1.0]).wait();
            (ctx.client_id, ctx.mpi_rank, v[0])
        });
        // Each client has 2 workers: allreduce sums within the client only.
        for (client, rank, sum) in out {
            assert!(client < 2 && rank < 2);
            assert_eq!(sum, 2.0);
        }
    }

    #[test]
    fn launch_dist_job_with_servers() {
        let spec = JobSpec::from_algo(Algo::DistSgd, 3, 2, 3);
        assert_eq!(spec.expected_pushes(), 3);
        let out = launch(&spec, |ctx| {
            if ctx.ps_rank == 0 {
                ctx.kv.init(0, vec![0.0], true);
                ctx.kv.set_optimizer(|| {
                    Box::new(crate::optimizer::Sgd::new(
                        crate::optimizer::SgdHyper::plain(1.0, 1.0),
                    ))
                });
            }
            ctx.kv.push(0, vec![1.0]);
            ctx.kv.pull(0).wait()[0]
        });
        for v in out {
            assert_eq!(v, -3.0);
        }
    }

    #[test]
    fn mpi_job_with_servers_masters_push() {
        let spec = JobSpec::from_algo(Algo::MpiSgd, 4, 1, 2);
        assert_eq!(spec.expected_pushes(), 2);
        let out = launch(&spec, |ctx| {
            if ctx.ps_rank == 0 {
                ctx.kv.init(0, vec![0.0], true);
                ctx.kv.set_optimizer(|| {
                    Box::new(crate::optimizer::Sgd::new(
                        crate::optimizer::SgdHyper::plain(1.0, 1.0),
                    ))
                });
            }
            ctx.kv.push(0, vec![1.0]);
            ctx.kv.pull(0).wait()[0]
        });
        // 2 clients x client-sum 2.0 => server applies w = 0 - 4.
        for v in out {
            assert_eq!(v, -4.0);
        }
    }

    #[test]
    #[should_panic(expected = "divide evenly")]
    fn uneven_clients_rejected() {
        let spec = JobSpec {
            workers: 5,
            servers: 0,
            clients: 2,
            ktype: KvType::SyncMpi,
            server_mode: SyncMode::Sync,
            engine_threads: 1,
            collective: AlgoKind::Ring,
            fusion_bytes: 0,
            rings: 2,
            group: 2,
            cost: CostParams::testbed1(),
        };
        launch(&spec, |_| ());
    }
}
