//! Optimizers: worker- and server-side update rules (paper §2, §5).
//!
//! The KVStore ships an optimizer to the servers (`set_optimizer`, §3.2):
//! per-key updates run where the paper runs them — `SgdScaled` on the PS for
//! dist/mpi-(A)SGD, `Elastic1` (eq. 2) on the PS for ESGD — while workers
//! apply `Sgd` locally (pure-MPI mode) and `Elastic2` (eq. 3) inside the
//! MPI client. These Rust implementations are the per-key reference used by
//! the PS servers; on the full-flat-vector training path the AOT-compiled
//! Pallas kernels (`sgd_*.hlo.txt`, `elastic*_*.hlo.txt`) do the same math
//! through PJRT, and tests cross-check the two.



/// Hyper-parameters of the fused SGD kernel: `(lr, momentum, wd, rescale)`.
#[derive(Debug, Clone, Copy)]
pub struct SgdHyper {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// 1 / mini_batch_size (§5: gradients are rescaled by the *algorithm*
    /// mini-batch, which grows with the number of workers aggregated).
    pub rescale: f32,
}

impl SgdHyper {
    pub fn plain(lr: f32, rescale: f32) -> Self {
        Self { lr, momentum: 0.0, weight_decay: 0.0, rescale }
    }

    pub fn as_vec(&self) -> Vec<f32> {
        vec![self.lr, self.momentum, self.weight_decay, self.rescale]
    }
}

/// A stateful per-key update rule, applied where the algorithm places it.
pub trait Optimizer: Send {
    /// Apply an update to `weights` given an aggregated `grad`.
    fn update(&mut self, key: usize, weights: &mut [f32], grad: &[f32]);
    fn name(&self) -> &'static str;
}

/// Fused momentum SGD with weight decay and gradient rescale — the math of
/// the `sgd_update` Pallas kernel.
pub struct Sgd {
    pub hyper: SgdHyper,
    momentum_buf: std::collections::HashMap<usize, Vec<f32>>,
}

impl Sgd {
    pub fn new(hyper: SgdHyper) -> Self {
        Self { hyper, momentum_buf: Default::default() }
    }
}

impl Optimizer for Sgd {
    fn update(&mut self, key: usize, weights: &mut [f32], grad: &[f32]) {
        let h = self.hyper;
        let m = self
            .momentum_buf
            .entry(key)
            .or_insert_with(|| vec![0.0; weights.len()]);
        assert_eq!(m.len(), weights.len());
        for i in 0..weights.len() {
            let g_eff = h.rescale * grad[i] + h.weight_decay * weights[i];
            m[i] = h.momentum * m[i] + g_eff;
            weights[i] -= h.lr * m[i];
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// "Optimizer" that just stores the aggregated value. This is MXNET's
/// default dist-sync server behaviour in the Fig. 6 algorithm: the server
/// only *aggregates* gradients; workers pull the sum back and run
/// `SGD.Update` locally with `rescale = 1/mini_batch_size`.
pub struct Assign;

impl Optimizer for Assign {
    fn update(&mut self, _key: usize, value: &mut [f32], agg: &[f32]) {
        value.copy_from_slice(agg);
    }

    fn name(&self) -> &'static str {
        "assign"
    }
}

/// AdaGrad (§3.2 lists it among the optimizers the KVStore can ship).
pub struct AdaGrad {
    pub lr: f32,
    pub rescale: f32,
    pub eps: f32,
    accum: std::collections::HashMap<usize, Vec<f32>>,
}

impl AdaGrad {
    pub fn new(lr: f32, rescale: f32) -> Self {
        Self { lr, rescale, eps: 1e-8, accum: Default::default() }
    }
}

impl Optimizer for AdaGrad {
    fn update(&mut self, key: usize, weights: &mut [f32], grad: &[f32]) {
        let a = self
            .accum
            .entry(key)
            .or_insert_with(|| vec![0.0; weights.len()]);
        for i in 0..weights.len() {
            let g = self.rescale * grad[i];
            a[i] += g * g;
            weights[i] -= self.lr * g / (a[i].sqrt() + self.eps);
        }
    }

    fn name(&self) -> &'static str {
        "adagrad"
    }
}

/// Server-side elastic update (eq. 2): treats the *pushed value* as the
/// client's current weights `w` and moves the stored center variables
/// towards them: `c <- c + alpha (w - c)`.
pub struct Elastic1 {
    pub alpha: f32,
}

impl Optimizer for Elastic1 {
    fn update(&mut self, _key: usize, center: &mut [f32], w: &[f32]) {
        for i in 0..center.len() {
            center[i] += self.alpha * (w[i] - center[i]);
        }
    }

    fn name(&self) -> &'static str {
        "elastic1"
    }
}

/// Client-side elastic update (eq. 3): `w <- w - alpha (w - c)`, where `c`
/// is the center pulled from the PS *before* the server applied eq. 2 —
/// both sides use the same pre-update difference (Fig. 8).
pub fn elastic2(w: &mut [f32], center: &[f32], alpha: f32) {
    for i in 0..w.len() {
        w[i] -= alpha * (w[i] - center[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_plain_matches_formula() {
        let mut o = Sgd::new(SgdHyper::plain(0.5, 1.0));
        let mut w = vec![1.0, 2.0];
        o.update(0, &mut w, &[0.2, -0.4]);
        assert_eq!(w, vec![0.9, 2.2]);
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let mut o = Sgd::new(SgdHyper { lr: 1.0, momentum: 0.5, weight_decay: 0.0, rescale: 1.0 });
        let mut w = vec![0.0];
        o.update(0, &mut w, &[1.0]); // m=1, w=-1
        o.update(0, &mut w, &[1.0]); // m=1.5, w=-2.5
        assert!((w[0] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn sgd_rescale_divides_batch() {
        let mut o = Sgd::new(SgdHyper::plain(1.0, 1.0 / 4.0));
        let mut w = vec![0.0];
        o.update(0, &mut w, &[8.0]);
        assert_eq!(w, vec![-2.0]);
    }

    #[test]
    fn sgd_weight_decay_pulls_to_zero() {
        let mut o = Sgd::new(SgdHyper { lr: 0.1, momentum: 0.0, weight_decay: 0.5, rescale: 1.0 });
        let mut w = vec![2.0];
        o.update(0, &mut w, &[0.0]);
        assert!((w[0] - 1.9).abs() < 1e-6);
    }

    #[test]
    fn sgd_separate_keys_have_separate_momentum() {
        let mut o = Sgd::new(SgdHyper { lr: 1.0, momentum: 0.9, weight_decay: 0.0, rescale: 1.0 });
        let mut w0 = vec![0.0];
        let mut w1 = vec![0.0];
        o.update(0, &mut w0, &[1.0]);
        o.update(1, &mut w1, &[1.0]);
        assert_eq!(w0, w1); // first step identical => buffers independent
    }

    #[test]
    fn adagrad_decreases_effective_lr() {
        let mut o = AdaGrad::new(1.0, 1.0);
        let mut w = vec![0.0];
        o.update(0, &mut w, &[1.0]);
        let step1 = -w[0];
        let before = w[0];
        o.update(0, &mut w, &[1.0]);
        let step2 = before - w[0];
        assert!(step2 < step1);
    }

    #[test]
    fn elastic_updates_match_equations() {
        let alpha = 0.25;
        let mut c = vec![0.0, 4.0];
        let w = vec![4.0, 0.0];
        Elastic1 { alpha }.update(0, &mut c, &w);
        assert_eq!(c, vec![1.0, 3.0]);

        let mut w2 = vec![4.0, 0.0];
        let c2 = vec![0.0, 4.0];
        elastic2(&mut w2, &c2, alpha);
        assert_eq!(w2, vec![3.0, 1.0]);
    }

    #[test]
    fn elastic_is_symmetric_attraction() {
        // After eq.2 + eq.3 from the same (w, c), the pair moves towards
        // each other by the same amount: w' - c' = (1 - 2a)(w - c).
        let alpha = 0.3f32;
        let w0 = 5.0f32;
        let c0 = 1.0f32;
        let mut c = vec![c0];
        Elastic1 { alpha }.update(0, &mut c, &[w0]);
        let mut w = vec![w0];
        elastic2(&mut w, &[c0], alpha);
        let got = w[0] - c[0];
        let want = (1.0 - 2.0 * alpha) * (w0 - c0);
        assert!((got - want).abs() < 1e-6);
    }
}
