//! Gradient compression plane: pluggable codecs that shrink the bytes on
//! the wire (the complement of the §6 collective optimizations — Shi et
//! al., arXiv:1711.05979, show distributed DL is communication-bound on
//! exactly the gradient-exchange path this repo models).
//!
//! Three halves, mirroring `trainer/strategies/`:
//!
//! * [`Compressor`] — one trait per codec, stateless: `compress` maps a
//!   dense f32 buffer to a [`Compressed`] payload. Shipping codecs:
//!   `identity` (no-op: every compressed code path delegates to the
//!   pre-compression implementation, bitwise), `int8` linear quantization
//!   with a per-bucket scale ([`INT8_BUCKET`] elements per scale), and
//!   `topk` sparsification (largest-|v| index/value pairs,
//!   [`TopK::ratio`] of the elements).
//! * **Error feedback** ([`EfState`] / [`ef_compress`]) — the residual
//!   `input − decode(compress(input))` is accumulated per buffer and added
//!   back into the *next* compression of that buffer (Seide et al. 2014;
//!   Karimireddy et al. 2019), so lossy codecs stay unbiased over time:
//!   `Σ decodes + residual == Σ inputs` exactly (up to f32 association) —
//!   the invariant the tests pin.
//! * **Wire format** — payloads travel as `Vec<f32>` (the
//!   [`crate::mpisim`] message type) via [`Compressed::to_wire`], packing
//!   four int8 codes or one u32 index per f32 *bit pattern*, so the wire
//!   word count is the real compressed size: the data path, the modeled
//!   cost ([`Compressor::wire_bytes`]) and the bench wire-bytes column all
//!   agree. [`Compressed::from_wire`] is self-describing — a PS server can
//!   decode a push without knowing which codec the worker ran.
//!
//! The string-keyed [`registry`] drives `--compression` parsing, usage
//! text, the `fig_compress` sweep and the CI smoke matrix, so none of them
//! can drift from the set of codecs that actually run.

use crate::netsim::CostParams;
use crate::tensor::add_assign;
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;
use std::sync::OnceLock;

/// Elements per int8 quantization scale (one f32 scale amortized over this
/// many codes keeps the header overhead at ~0.2%).
pub const INT8_BUCKET: usize = 2048;

/// Wire header: [kind, len, kind-specific] as u32 bit patterns.
const HEADER_WORDS: usize = 3;
const WIRE_DENSE: u32 = 0;
const WIRE_INT8: u32 = 1;
const WIRE_TOPK: u32 = 2;

// ---------------------------------------------------------------------------
// Compressed payloads + the wire format
// ---------------------------------------------------------------------------

/// A compressed gradient payload. Decompression is codec-independent (the
/// payload is self-describing), which is what lets a PS server decode any
/// worker's push without holding the worker's codec object.
#[derive(Debug, Clone, PartialEq)]
pub enum Compressed {
    /// Uncompressed (the identity codec; also the fallback wire form).
    Dense(Vec<f32>),
    /// Per-bucket linear int8: `v ≈ q * scales[i / bucket]`, codes packed
    /// four per u32 word.
    Int8 {
        len: usize,
        bucket: usize,
        scales: Vec<f32>,
        packed: Vec<u32>,
    },
    /// Top-k sparsification: `len`-element vector with `idx.len()`
    /// surviving (index, value) pairs, indices ascending.
    TopK {
        len: usize,
        idx: Vec<u32>,
        vals: Vec<f32>,
    },
}

impl Compressed {
    /// Dense element count of the original buffer.
    pub fn len(&self) -> usize {
        match self {
            Compressed::Dense(v) => v.len(),
            Compressed::Int8 { len, .. } | Compressed::TopK { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decode back to a dense buffer.
    pub fn decompress(&self) -> Vec<f32> {
        match self {
            Compressed::Dense(v) => v.clone(),
            Compressed::Int8 { len, bucket, scales, packed } => {
                let mut out = vec![0.0f32; *len];
                for (i, o) in out.iter_mut().enumerate() {
                    let code = unpack_i8(packed, i);
                    *o = code as f32 * scales[i / bucket];
                }
                out
            }
            Compressed::TopK { len, idx, vals } => {
                let mut out = vec![0.0f32; *len];
                for (&i, &v) in idx.iter().zip(vals) {
                    out[i as usize] = v;
                }
                out
            }
        }
    }

    /// Payload size in f32 words as it travels through [`crate::mpisim`].
    pub fn wire_words(&self) -> usize {
        HEADER_WORDS
            + match self {
                Compressed::Dense(v) => v.len(),
                Compressed::Int8 { scales, packed, .. } => scales.len() + packed.len(),
                Compressed::TopK { idx, vals, .. } => idx.len() + vals.len(),
            }
    }

    /// Payload size in bytes (4 × [`Compressed::wire_words`]).
    pub fn wire_bytes(&self) -> usize {
        self.wire_words() * 4
    }

    /// Serialize into the `Vec<f32>` carrier the mpisim transport moves.
    /// Non-float words (codes, indices, lengths) ride as raw bit patterns;
    /// the transport only ever memcpys them, so the bits survive.
    pub fn to_wire(&self) -> Vec<f32> {
        let mut w = Vec::with_capacity(self.wire_words());
        match self {
            Compressed::Dense(v) => {
                w.push(f32::from_bits(WIRE_DENSE));
                w.push(f32::from_bits(v.len() as u32));
                w.push(f32::from_bits(0));
                w.extend_from_slice(v);
            }
            Compressed::Int8 { len, bucket, scales, packed } => {
                w.push(f32::from_bits(WIRE_INT8));
                w.push(f32::from_bits(*len as u32));
                w.push(f32::from_bits(*bucket as u32));
                w.extend_from_slice(scales);
                w.extend(packed.iter().map(|&u| f32::from_bits(u)));
            }
            Compressed::TopK { len, idx, vals } => {
                w.push(f32::from_bits(WIRE_TOPK));
                w.push(f32::from_bits(*len as u32));
                w.push(f32::from_bits(idx.len() as u32));
                w.extend(idx.iter().map(|&u| f32::from_bits(u)));
                w.extend_from_slice(vals);
            }
        }
        w
    }

    /// Parse a wire payload (inverse of [`Compressed::to_wire`]).
    pub fn from_wire(w: &[f32]) -> Result<Compressed> {
        ensure!(w.len() >= HEADER_WORDS, "compressed payload shorter than its header");
        let kind = w[0].to_bits();
        let len = w[1].to_bits() as usize;
        let aux = w[2].to_bits() as usize;
        let body = &w[HEADER_WORDS..];
        match kind {
            WIRE_DENSE => {
                ensure!(body.len() == len, "dense payload length mismatch");
                Ok(Compressed::Dense(body.to_vec()))
            }
            WIRE_INT8 => {
                let bucket = aux;
                ensure!(bucket > 0, "int8 payload with zero bucket");
                let nb = len.div_ceil(bucket);
                let np = len.div_ceil(4);
                ensure!(body.len() == nb + np, "int8 payload length mismatch");
                Ok(Compressed::Int8 {
                    len,
                    bucket,
                    scales: body[..nb].to_vec(),
                    packed: body[nb..].iter().map(|f| f.to_bits()).collect(),
                })
            }
            WIRE_TOPK => {
                let k = aux;
                ensure!(k <= len, "topk payload keeps more elements than it has");
                ensure!(body.len() == 2 * k, "topk payload length mismatch");
                let idx: Vec<u32> = body[..k].iter().map(|f| f.to_bits()).collect();
                ensure!(
                    idx.iter().all(|&i| (i as usize) < len),
                    "topk index out of range"
                );
                Ok(Compressed::TopK { len, idx, vals: body[k..].to_vec() })
            }
            other => bail!("unknown compressed payload kind {other}"),
        }
    }
}

fn unpack_i8(packed: &[u32], i: usize) -> i8 {
    ((packed[i / 4] >> ((i % 4) * 8)) & 0xFF) as u8 as i8
}

fn pack_i8(packed: &mut [u32], i: usize, code: i8) {
    packed[i / 4] |= ((code as u8) as u32) << ((i % 4) * 8);
}

// ---------------------------------------------------------------------------
// The trait + shipping codecs
// ---------------------------------------------------------------------------

/// A gradient codec. Stateless (error-feedback residuals live in
/// [`EfState`], keyed per buffer), so one `Arc` serves every worker thread.
pub trait Compressor: Send + Sync {
    fn name(&self) -> &'static str;

    /// Identity codecs make every compressed code path delegate to the
    /// pre-compression implementation — bitwise-equal, regression-tested.
    fn is_identity(&self) -> bool {
        false
    }

    /// Encode a dense buffer. Must be deterministic.
    fn compress(&self, data: &[f32]) -> Compressed;

    /// Modeled wire bytes for an `n`-element dense buffer — must equal the
    /// data path's `compress(..).wire_bytes()` (asserted in tests) so the
    /// α-β-γ models price exactly what mpisim moves. Identity reports the
    /// raw dense bytes (no header: its payloads never take the compressed
    /// wire path).
    fn wire_bytes(&self, n: usize) -> usize;
}

/// The no-op codec: dense bytes, pre-compression code paths.
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> &'static str {
        "identity"
    }
    fn is_identity(&self) -> bool {
        true
    }
    fn compress(&self, data: &[f32]) -> Compressed {
        Compressed::Dense(data.to_vec())
    }
    fn wire_bytes(&self, n: usize) -> usize {
        n * 4
    }
}

/// Per-bucket linear int8 quantization: `scale = max|v| / 127` over each
/// [`INT8_BUCKET`]-element bucket, `q = round(v / scale)` clamped to
/// ±127 — 4 bytes → ~1 byte, worst-case per-element error `scale / 2`.
pub struct Int8 {
    pub bucket: usize,
}

impl Compressor for Int8 {
    fn name(&self) -> &'static str {
        "int8"
    }
    fn compress(&self, data: &[f32]) -> Compressed {
        let n = data.len();
        let bucket = self.bucket.max(1);
        let nb = n.div_ceil(bucket);
        let mut scales = Vec::with_capacity(nb);
        let mut packed = vec![0u32; n.div_ceil(4)];
        for b in 0..nb {
            let lo = b * bucket;
            let hi = (lo + bucket).min(n);
            let maxabs = maxabs_lanes(&data[lo..hi]);
            let scale = maxabs / 127.0;
            scales.push(scale);
            if scale > 0.0 {
                quantize_bucket(&data[lo..hi], lo, scale, &mut packed);
            }
        }
        Compressed::Int8 { len: n, bucket, scales, packed }
    }
    fn wire_bytes(&self, n: usize) -> usize {
        let bucket = self.bucket.max(1);
        4 * (HEADER_WORDS + n.div_ceil(bucket) + n.div_ceil(4))
    }
}

/// max|v| over a bucket with eight parallel accumulators. f32 max is
/// exactly associative and commutative on the NaN-free gradients this
/// plane carries, so the chunked fold is bitwise-identical to the old
/// sequential fold while giving the compiler a vectorizable shape.
fn maxabs_lanes(data: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    let mut it = data.chunks_exact(8);
    for c in &mut it {
        for (a, &v) in acc.iter_mut().zip(c) {
            *a = a.max(v.abs());
        }
    }
    let mut m = acc.iter().fold(0.0f32, |a, &v| a.max(v));
    for &v in it.remainder() {
        m = m.max(v.abs());
    }
    m
}

/// Quantize one bucket into the shared packed words. Interior aligned
/// words are built whole in registers and stored once (the old
/// per-element read-modify-write on a shared word defeated
/// autovectorization); only the few elements straddling the bucket's
/// word boundaries take the byte path. Emits exactly the bytes of the
/// per-element reference (regression-tested bitwise below).
fn quantize_bucket(data: &[f32], lo: usize, scale: f32, packed: &mut [u32]) {
    let q = |v: f32| (v / scale).round().clamp(-127.0, 127.0) as i8;
    let head = ((4 - lo % 4) % 4).min(data.len());
    for (i, &v) in data[..head].iter().enumerate() {
        pack_i8(packed, lo + i, q(v));
    }
    let body = &data[head..];
    let mut w = (lo + head) / 4;
    let mut it = body.chunks_exact(4);
    for c in &mut it {
        packed[w] = (q(c[0]) as u8 as u32)
            | ((q(c[1]) as u8 as u32) << 8)
            | ((q(c[2]) as u8 as u32) << 16)
            | ((q(c[3]) as u8 as u32) << 24);
        w += 1;
    }
    let done = head + 4 * (body.len() / 4);
    for (i, &v) in it.remainder().iter().enumerate() {
        pack_i8(packed, lo + done + i, q(v));
    }
}

/// Top-k sparsification: keep the `ratio` fraction of elements with the
/// largest |v| (at least one), ties broken by index so the selection is
/// deterministic. Everything dropped lands in the error-feedback residual.
pub struct TopK {
    pub ratio: f64,
}

impl TopK {
    /// Survivor count for an `n`-element buffer.
    pub fn k_of(&self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        ((n as f64 * self.ratio).ceil() as usize).clamp(1, n)
    }
}

impl Compressor for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }
    fn compress(&self, data: &[f32]) -> Compressed {
        let n = data.len();
        let k = self.k_of(n);
        // O(n) partial selection over *contiguous magnitudes* (a full
        // sort of 26M gradient elements per iteration would dominate the
        // codec, and selecting through an index vec defeats the cache):
        // quickselect the k-th largest |v| as a threshold, then one
        // vectorizable sweep keeps everything above it plus the first
        // (by index) ties at it. The (|v| desc, index asc) total order
        // makes the selected set unique, so this is bitwise-identical to
        // selecting on (|v|, index) pairs directly (regression-tested).
        let mut idx: Vec<u32> = if k < n {
            let mut mag: Vec<f32> = data.iter().map(|v| v.abs()).collect();
            let (_, thr, _) = mag.select_nth_unstable_by(k - 1, |a, b| b.total_cmp(a));
            let thr = *thr;
            let mut keep = Vec::with_capacity(k);
            let mut ties: Vec<u32> = Vec::new();
            for (i, v) in data.iter().enumerate() {
                match v.abs().total_cmp(&thr) {
                    std::cmp::Ordering::Greater => keep.push(i as u32),
                    std::cmp::Ordering::Equal => ties.push(i as u32),
                    std::cmp::Ordering::Less => {}
                }
            }
            let need = k - keep.len();
            keep.extend(&ties[..need]);
            keep
        } else {
            (0..n as u32).collect()
        };
        idx.sort_unstable();
        let vals: Vec<f32> = idx.iter().map(|&i| data[i as usize]).collect();
        Compressed::TopK { len: n, idx, vals }
    }
    fn wire_bytes(&self, n: usize) -> usize {
        4 * (HEADER_WORDS + 2 * self.k_of(n))
    }
}

// ---------------------------------------------------------------------------
// Error feedback
// ---------------------------------------------------------------------------

/// Per-buffer error-feedback residuals, keyed by an opaque u64 the caller
/// namespaces (KVStore key, fusion-bucket id, master-hop id, …).
#[derive(Default)]
pub struct EfState {
    residual: HashMap<u64, Vec<f32>>,
}

impl EfState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current residual for `key` (tests / diagnostics).
    pub fn residual(&self, key: u64) -> Option<&[f32]> {
        self.residual.get(&key).map(|v| v.as_slice())
    }

    pub fn clear(&mut self) {
        self.residual.clear();
    }
}

/// Error-feedback compression of one buffer: add the buffer's accumulated
/// residual, compress, and store `input + residual − decode` as the new
/// residual — so what the codec drops this round is carried into the next
/// (`Σ decodes + residual == Σ inputs`, the EF invariant). Identity codecs
/// pass through with a forever-zero residual.
pub fn ef_compress(
    codec: &dyn Compressor,
    key: u64,
    data: &[f32],
    st: &mut EfState,
) -> Compressed {
    if codec.is_identity() {
        return Compressed::Dense(data.to_vec());
    }
    let mut v = data.to_vec();
    ef_compress_in_place(codec, key, &mut v, st)
}

/// [`ef_compress`] minus the defensive copy: the residual is added into
/// `data` in place, the codec encodes straight out of it (the zero-copy
/// fused path passes a fusion-arena slice here), and the new residual is
/// rewritten into its existing buffer — no per-call allocation once the
/// key is warm. `data` is left holding input + residual; callers that
/// still need the raw input must use [`ef_compress`].
pub fn ef_compress_in_place(
    codec: &dyn Compressor,
    key: u64,
    data: &mut [f32],
    st: &mut EfState,
) -> Compressed {
    if codec.is_identity() {
        return Compressed::Dense(data.to_vec());
    }
    if let Some(r) = st.residual.get(&key) {
        if r.len() == data.len() {
            add_assign(data, r);
        }
    }
    let c = codec.compress(data);
    let dec = c.decompress();
    let resid = st.residual.entry(key).or_default();
    resid.clear();
    resid.reserve(data.len());
    resid.extend(data.iter().zip(&dec).map(|(v, dv)| v - dv));
    c
}

/// What the receivers decode after an EF compression of `data` — the sim
/// plane applies this round-trip to its gradients so lossy codecs affect
/// the *numerics* (convergence curves), not just the wire-byte pricing.
pub fn ef_roundtrip(
    codec: &dyn Compressor,
    key: u64,
    data: &[f32],
    st: &mut EfState,
) -> Vec<f32> {
    if codec.is_identity() {
        return data.to_vec();
    }
    ef_compress(codec, key, data, st).decompress()
}

/// Modeled codec compute seconds for one encode + one decode of a
/// `dense_bytes` buffer (the γ term the cost models add per compressed
/// hop). Identity is free — its code paths never run a codec.
pub fn codec_seconds(codec: &dyn Compressor, dense_bytes: usize, params: &CostParams) -> f64 {
    if codec.is_identity() {
        0.0
    } else {
        2.0 * dense_bytes as f64 * params.gamma_codec
    }
}

// ---------------------------------------------------------------------------
// Registry — mirrors trainer/strategies: one entry per codec, every
// consumer (CLI, config, figures, bench, CI matrix) derives from it.
// ---------------------------------------------------------------------------

/// One registered codec: name, docs metadata and a factory (the `f64`
/// argument is the config's `topk_ratio`; codecs that don't need it ignore
/// it).
pub struct CodecEntry {
    pub name: &'static str,
    /// Human description for usage text / docs.
    pub description: &'static str,
    pub build: fn(f64) -> Box<dyn Compressor>,
}

/// The codec registry. Adding a codec is one impl plus one entry here.
pub fn registry() -> &'static [CodecEntry] {
    static REGISTRY: OnceLock<Vec<CodecEntry>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        vec![
            CodecEntry {
                name: "identity",
                description: "no compression (bitwise pre-compression paths)",
                build: |_| Box::new(Identity),
            },
            CodecEntry {
                name: "int8",
                description: "per-bucket linear int8 quantization + error feedback (~4x)",
                build: |_| Box::new(Int8 { bucket: INT8_BUCKET }),
            },
            CodecEntry {
                name: "topk",
                description: "top-k sparsification + error feedback (--topk-ratio)",
                build: |ratio| Box::new(TopK { ratio }),
            },
        ]
    })
}

/// A registered codec handle — `Copy`, resolved by name, mirroring
/// [`crate::config::Algo`].
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Codec(u16);

impl Codec {
    /// Case-insensitive name lookup ("none" is accepted for "identity").
    pub fn parse(s: &str) -> Option<Codec> {
        let s = if s.eq_ignore_ascii_case("none") { "identity" } else { s };
        registry()
            .iter()
            .position(|e| e.name.eq_ignore_ascii_case(s))
            .map(|i| Codec(i as u16))
    }

    /// Lookup that panics (listing the registered names) on a miss.
    pub fn named(s: &str) -> Codec {
        Self::parse(s).unwrap_or_else(|| {
            panic!(
                "unknown compression codec {s:?} (registered: {})",
                Self::names().join(", ")
            )
        })
    }

    pub fn identity() -> Codec {
        Self::named("identity")
    }

    /// Every registered codec, registration order.
    pub fn all() -> Vec<Codec> {
        (0..registry().len()).map(|i| Codec(i as u16)).collect()
    }

    /// Every registered name, registration order (usage text, errors).
    pub fn names() -> Vec<&'static str> {
        registry().iter().map(|e| e.name).collect()
    }

    pub fn entry(&self) -> &'static CodecEntry {
        &registry()[self.0 as usize]
    }

    pub fn name(&self) -> &'static str {
        self.entry().name
    }

    pub fn is_identity(&self) -> bool {
        self.name() == "identity"
    }

    /// Instantiate the codec (`topk_ratio` is ignored by non-topk codecs).
    pub fn build(&self, topk_ratio: f64) -> Box<dyn Compressor> {
        (self.entry().build)(topk_ratio)
    }
}

impl std::fmt::Debug for Codec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(n: usize, seed: u64) -> Vec<f32> {
        let mut r = crate::util::Rng::new(seed);
        (0..n)
            .map(|_| (r.below(2001) as i64 - 1000) as f32 * 0.01)
            .collect()
    }

    #[test]
    fn registry_round_trips_and_has_three_codecs() {
        assert_eq!(Codec::names(), vec!["identity", "int8", "topk"]);
        for c in Codec::all() {
            assert_eq!(Codec::parse(c.name()), Some(c));
            assert_eq!(Codec::parse(&c.name().to_ascii_uppercase()), Some(c));
        }
        assert_eq!(Codec::parse("none"), Some(Codec::identity()));
        assert_eq!(Codec::parse("zip9"), None);
        assert!(Codec::identity().is_identity());
        assert!(Codec::identity().build(0.5).is_identity());
    }

    #[test]
    fn identity_round_trip_is_exact() {
        let codec = Identity;
        let data = payload(100, 1);
        let c = codec.compress(&data);
        assert_eq!(c.decompress(), data);
        assert_eq!(codec.wire_bytes(100), 400);
    }

    #[test]
    fn int8_error_bounded_by_half_scale() {
        let codec = Int8 { bucket: 64 };
        for n in [1usize, 63, 64, 65, 1000] {
            let data = payload(n, n as u64);
            let c = codec.compress(&data);
            let dec = c.decompress();
            let maxabs = data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            // Bucket maxabs <= global maxabs => per-element error <= the
            // bucket's scale/2 <= global maxabs/254 (plus rounding fuzz).
            let bound = maxabs / 254.0 * 1.01 + 1e-7;
            for (d, o) in dec.iter().zip(&data) {
                assert!((d - o).abs() <= bound, "n={n}: {o} -> {d} (bound {bound})");
            }
        }
    }

    #[test]
    fn int8_all_zero_bucket_stays_zero() {
        let codec = Int8 { bucket: 8 };
        let c = codec.compress(&[0.0; 20]);
        assert_eq!(c.decompress(), vec![0.0; 20]);
    }

    #[test]
    fn topk_keeps_exactly_the_largest() {
        let codec = TopK { ratio: 0.25 };
        let data = vec![0.1, -5.0, 0.2, 3.0, -0.3, 0.0, 1.0, -0.05];
        let c = codec.compress(&data); // k = 2
        let dec = c.decompress();
        assert_eq!(dec, vec![0.0, -5.0, 0.0, 3.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn topk_ties_break_by_index_deterministically() {
        let codec = TopK { ratio: 0.5 };
        let data = vec![1.0, -1.0, 1.0, -1.0];
        let c = codec.compress(&data); // k = 2: first two by index
        assert_eq!(c.decompress(), vec![1.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn wire_round_trip_bitwise_all_codecs() {
        let data = payload(300, 7);
        for codec in Codec::all() {
            let built = codec.build(0.1);
            let c = built.compress(&data);
            let wire = c.to_wire();
            let back = Compressed::from_wire(&wire).unwrap();
            assert_eq!(back, c, "{}", codec.name());
            assert_eq!(back.decompress(), c.decompress());
            assert_eq!(wire.len() * 4, c.wire_bytes(), "{}", codec.name());
        }
    }

    #[test]
    fn modeled_wire_bytes_match_data_path() {
        // The α-β-γ models must price exactly what mpisim moves.
        for n in [1usize, 17, 100, 2048, 5000] {
            let data = payload(n, n as u64 + 9);
            for codec in Codec::all() {
                let built = codec.build(0.05);
                let modeled = built.wire_bytes(n);
                let actual = built.compress(&data).wire_bytes();
                if codec.is_identity() {
                    // Identity models the raw dense bytes (its payloads
                    // never take the compressed wire path).
                    assert_eq!(modeled, n * 4);
                } else {
                    assert_eq!(modeled, actual, "{} n={n}", codec.name());
                }
            }
        }
    }

    #[test]
    fn compressed_wire_smaller_than_dense() {
        let n = 100_000;
        let int8 = Int8 { bucket: INT8_BUCKET };
        let topk = TopK { ratio: 0.01 };
        assert!(int8.wire_bytes(n) < n * 4 / 3, "{}", int8.wire_bytes(n));
        assert!(topk.wire_bytes(n) < n * 4 / 10, "{}", topk.wire_bytes(n));
    }

    #[test]
    fn from_wire_rejects_garbage() {
        assert!(Compressed::from_wire(&[]).is_err());
        let mut w = Compressed::Dense(vec![1.0; 4]).to_wire();
        w.pop();
        assert!(Compressed::from_wire(&w).is_err());
        let w = vec![f32::from_bits(99), f32::from_bits(1), f32::from_bits(0)];
        assert!(Compressed::from_wire(&w).is_err());
        // A zero-length topk payload claiming k=1 must be rejected (its
        // index would read out of bounds on decompress), as must any
        // index >= len.
        let w = vec![
            f32::from_bits(WIRE_TOPK),
            f32::from_bits(0),
            f32::from_bits(1),
            f32::from_bits(0),
            1.0,
        ];
        assert!(Compressed::from_wire(&w).is_err());
        let w = vec![
            f32::from_bits(WIRE_TOPK),
            f32::from_bits(4),
            f32::from_bits(1),
            f32::from_bits(4), // index == len
            1.0,
        ];
        assert!(Compressed::from_wire(&w).is_err());
    }

    #[test]
    fn error_feedback_invariant_sum_of_decodes() {
        // Σ decodes + residual == Σ inputs (up to f32 association): feed T
        // varying gradients through EF and check the books balance.
        for codec in [
            Box::new(Int8 { bucket: 32 }) as Box<dyn Compressor>,
            Box::new(TopK { ratio: 0.1 }),
        ] {
            let mut st = EfState::new();
            let n = 200;
            let mut sum_in = vec![0.0f32; n];
            let mut sum_dec = vec![0.0f32; n];
            for t in 0..20u64 {
                let g = payload(n, 100 + t);
                add_assign(&mut sum_in, &g);
                let dec = ef_compress(&*codec, 7, &g, &mut st).decompress();
                add_assign(&mut sum_dec, &dec);
            }
            let resid = st.residual(7).unwrap();
            for i in 0..n {
                let lhs = sum_dec[i] + resid[i];
                assert!(
                    (lhs - sum_in[i]).abs() < 1e-3,
                    "{}: {} vs {}",
                    codec.name(),
                    lhs,
                    sum_in[i]
                );
            }
        }
    }

    #[test]
    fn ef_identity_never_accumulates_residual() {
        let mut st = EfState::new();
        let g = payload(50, 3);
        let c = ef_compress(&Identity, 1, &g, &mut st);
        assert_eq!(c.decompress(), g);
        assert!(st.residual(1).is_none());
        assert_eq!(ef_roundtrip(&Identity, 1, &g, &mut st), g);
    }

    #[test]
    fn ef_residual_resets_on_length_change() {
        // A stale residual of the wrong length (key reuse across shapes)
        // must be ignored, not panic or corrupt.
        let mut st = EfState::new();
        let codec = TopK { ratio: 0.5 };
        ef_compress(&codec, 1, &payload(10, 1), &mut st);
        let g = payload(6, 2);
        let dec = ef_compress(&codec, 1, &g, &mut st).decompress();
        assert_eq!(dec.len(), 6);
        assert_eq!(st.residual(1).unwrap().len(), 6);
    }

    #[test]
    fn codec_seconds_free_for_identity_positive_otherwise() {
        let p = CostParams::testbed1();
        assert_eq!(codec_seconds(&Identity, 1 << 20, &p), 0.0);
        assert!(codec_seconds(&Int8 { bucket: INT8_BUCKET }, 1 << 20, &p) > 0.0);
    }

    #[test]
    fn pack_unpack_i8_round_trips() {
        let mut packed = vec![0u32; 3];
        let codes: Vec<i8> = vec![-127, -1, 0, 1, 127, 64, -64, 3, -3];
        for (i, &c) in codes.iter().enumerate() {
            pack_i8(&mut packed, i, c);
        }
        for (i, &c) in codes.iter().enumerate() {
            assert_eq!(unpack_i8(&packed, i), c);
        }
    }

    /// The pre-vectorization int8 encoder: per-bucket double scan with a
    /// per-element read-modify-write pack. Kept verbatim as the bitwise
    /// reference for the single-pass/word-store rewrite.
    fn int8_compress_reference(bucket: usize, data: &[f32]) -> Compressed {
        let n = data.len();
        let bucket = bucket.max(1);
        let nb = n.div_ceil(bucket);
        let mut scales = Vec::with_capacity(nb);
        let mut packed = vec![0u32; n.div_ceil(4)];
        for b in 0..nb {
            let lo = b * bucket;
            let hi = (lo + bucket).min(n);
            let maxabs = data[lo..hi].iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let scale = maxabs / 127.0;
            scales.push(scale);
            if scale > 0.0 {
                for i in lo..hi {
                    let q = (data[i] / scale).round().clamp(-127.0, 127.0) as i8;
                    pack_i8(&mut packed, i, q);
                }
            }
        }
        Compressed::Int8 { len: n, bucket, scales, packed }
    }

    /// The pre-vectorization top-k encoder: quickselect over an index
    /// vector with a comparator on (|v| desc, index asc). Kept verbatim
    /// as the bitwise reference for the magnitude-threshold rewrite.
    fn topk_compress_reference(ratio: f64, data: &[f32]) -> Compressed {
        let n = data.len();
        let k = TopK { ratio }.k_of(n);
        let mut order: Vec<u32> = (0..n as u32).collect();
        let cmp = |a: &u32, b: &u32| {
            data[*b as usize]
                .abs()
                .total_cmp(&data[*a as usize].abs())
                .then(a.cmp(b))
        };
        if k > 0 && k < n {
            order.select_nth_unstable_by(k - 1, cmp);
            order.truncate(k);
        }
        let mut idx = order;
        idx.sort_unstable();
        let vals: Vec<f32> = idx.iter().map(|&i| data[i as usize]).collect();
        Compressed::TopK { len: n, idx, vals }
    }

    fn wire_bits(c: &Compressed) -> Vec<u32> {
        c.to_wire().iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn int8_vectorized_wire_bitwise_matches_reference() {
        // Bucket sizes deliberately not multiples of 4 so buckets
        // straddle packed words, plus all-zero and single-element cases.
        for bucket in [1usize, 3, 4, 7, 64, 2048] {
            for n in [0usize, 1, 3, 5, 63, 64, 65, 130, 1000] {
                let mut data = payload(n, 7 + n as u64);
                if n > 4 {
                    data[2] = 0.0;
                    data[4] = -0.0;
                }
                let new = Int8 { bucket }.compress(&data);
                let old = int8_compress_reference(bucket, &data);
                assert_eq!(
                    wire_bits(&new),
                    wire_bits(&old),
                    "int8 wire mismatch: bucket {bucket} n {n}"
                );
            }
        }
        // Entirely-zero buckets must emit zero scale and zero words.
        let zeros = vec![0.0f32; 40];
        for bucket in [3usize, 16] {
            let new = Int8 { bucket }.compress(&zeros);
            let old = int8_compress_reference(bucket, &zeros);
            assert_eq!(wire_bits(&new), wire_bits(&old));
        }
    }

    #[test]
    fn topk_partial_select_wire_bitwise_matches_reference() {
        for ratio in [0.01f64, 0.1, 0.5, 1.0] {
            for n in [0usize, 1, 2, 17, 64, 130, 1000] {
                // payload() quantizes to 0.01 steps, so duplicate
                // magnitudes (tie-break coverage) occur naturally; add
                // explicit ties and signed zeros on top.
                let mut data = payload(n, 1 + n as u64);
                if n > 8 {
                    data[1] = 0.25;
                    data[3] = -0.25;
                    data[5] = 0.25;
                    data[7] = 0.0;
                }
                let new = TopK { ratio }.compress(&data);
                let old = topk_compress_reference(ratio, &data);
                assert_eq!(
                    wire_bits(&new),
                    wire_bits(&old),
                    "topk wire mismatch: ratio {ratio} n {n}"
                );
            }
        }
    }

    #[test]
    fn ef_compress_in_place_matches_copying_path_and_reuses_buffer() {
        let codec = Int8 { bucket: 7 };
        let mut st_a = EfState::new();
        let mut st_b = EfState::new();
        for round in 0..4 {
            let g = payload(33, 100 + round);
            let a = ef_compress(&codec, 9, &g, &mut st_a);
            let mut buf = g.clone();
            let b = ef_compress_in_place(&codec, 9, &mut buf, &mut st_b);
            assert_eq!(wire_bits(&a), wire_bits(&b), "round {round}");
            assert_eq!(st_a.residual(9).unwrap(), st_b.residual(9).unwrap());
        }
    }
}
