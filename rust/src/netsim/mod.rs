//! Network/compute cost model + virtual clock (the hardware substitute).
//!
//! The paper's timing results come from Power8 testbeds (IB CX-4/CX-5
//! fabrics, NVLink'd P100s, 38.4 GB/s host write bandwidth per socket).
//! None of that hardware exists here, so every *timing* figure is driven by
//! this module: an α-β-γ cost model (the same formalism the paper uses in
//! §6.2 to analyse bucket algorithms) plus explicit link objects whose
//! serialization reproduces contention (the PS ingress hot spot of §2.3).
//!
//! Convergence numerics are *real* (PJRT-executed SGD); only the time axis
//! is virtual. See DESIGN.md §2 for the substitution table.


use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Seconds, on the virtual clock.
pub type VTime = f64;

/// α-β-γ parameters for the two paper testbeds.
///
/// β/γ values are seconds-per-byte (1/bandwidth); α is per-message latency.
/// Bandwidths are taken from the paper's §7.3 measurements where given.
#[derive(Debug, Clone)]
pub struct CostParams {
    /// Per-message network latency (MPI p2p), seconds.
    pub alpha_net: f64,
    /// Inter-node network for MPI (verbs/RDMA), s/byte (IB EDR ~12.5 GB/s).
    pub beta_net: f64,
    /// PS transport, s/byte. MXNET's ps-lite runs ZMQ over TCP — far below
    /// line rate on IB and prone to ingress incast — which is exactly why
    /// the paper moves aggregation into MPI cliques (§2.3, Fig. 12).
    pub beta_ps: f64,
    /// TCP incast coefficient at the PS ingress/egress: each additional
    /// concurrent flow queued on the link inflates its per-byte cost by
    /// this fraction (goodput collapse under fan-in, the §2.3 hot spot;
    /// cf. Project Adam [27]). MPI links (verbs) use 0.
    pub ps_incast: f64,
    /// Host memory write bandwidth, s/byte (38.4 GB/s per socket, §7.3).
    pub beta_hostmem: f64,
    /// Host-side single-thread reduction, s/byte.
    pub gamma_host: f64,
    /// Host-side 8-thread (OMP) reduction, s/byte (omp_ring design).
    pub gamma_omp: f64,
    /// GPU tensor reduction into host memory, IBMGpu kernels: 30 GB/s (§7.3).
    pub gamma_gpu_ibm: f64,
    /// Same via NCCL: 12 GB/s, one communicator set (§7.3).
    pub gamma_gpu_nccl: f64,
    /// GPU broadcast from host: 28 GB/s for both IBMGpu and NCCL (§7.3).
    pub beta_gpu_bcast: f64,
    /// Plain host<->device copy (the extra hops of the Baidu ring, §6.3).
    pub beta_h2d: f64,
    /// Per blocking GPU-op overhead (kernel launch + sync). NCCL ops are
    /// blocking (§7.3: "NCCL operations are blocking in nature"), so they
    /// pay this on every ring step; the IBMGpu design's GpuStart/GpuWait
    /// pipeline (Fig. 9) amortizes it per ring instead.
    pub gpu_sync: f64,
    /// GPUs per node-tensor (2 per Minsky socket-worker).
    pub gpus_per_worker: usize,
    /// Per-message latency on the intra-node device fabric
    /// (NVLink/shared-host-memory class), seconds. Sub-microsecond-class:
    /// device peers share a coherent fabric, no NIC or switch traversal.
    pub alpha_dev: f64,
    /// Intra-node device fabric bandwidth, s/byte (NVLink-class on
    /// Minsky, host-shared-memory class on testbed1). No incast term:
    /// the fabric is a crossbar/coherent bus, not a TCP ingress.
    pub beta_dev: f64,
    /// Devices per worker node sharing one NIC (MXNet `local` kvstore
    /// tier, SNIPPETS.md `multi_node.md`): k device ranks behind one
    /// inter-node link. Flat schedules pay `devices`-way NIC contention
    /// on `beta_net`; the two-tier schedule reduces locally first so only
    /// node leaders touch the NIC. Presets use 1 (flat world, all
    /// pre-device-tier pricing bitwise unchanged).
    pub devices: usize,
    /// Fabric-contention surcharge on the per-byte cost of recursive
    /// halving-doubling: its distance-2^k exchanges cross shared switch
    /// links, while bucket-ring traffic stays on neighbor links (Shi et
    /// al., arXiv:1711.05979). Drives the small/large-message crossover in
    /// [`crate::collectives::sim::select_best`].
    pub hd_contention: f64,
    /// Gradient-codec compute, s/byte of *dense* payload: one pass of the
    /// int8 quantization / top-k selection kernel (encode or decode).
    /// Memory-bandwidth-bound elementwise work, slower than a plain host
    /// copy but far above the TCP-class PS transport it saves bytes on.
    /// Identity codecs never pay it (their code paths run no codec).
    pub gamma_codec: f64,
    /// Sub-chunks per pipelined collective step (arXiv:1802.06949's
    /// chunked nonblocking schedules): each step's message moves as this
    /// many sub-messages so the per-step reduction overlaps the remaining
    /// transfers. 1 = blocking schedule. Both the data path
    /// ([`crate::collectives::allreduce_with`]) and the α-β-γ models /
    /// `select_best` autotuner read this, so modeled and real schedules
    /// agree.
    pub pipeline_chunks: usize,
    /// Fixed cost of a membership epoch: scheduler re-registration round
    /// trip plus tearing down and rebuilding the per-client MPI worlds
    /// (`mpirun` respawn scale, not kernel-launch scale — elasticity is a
    /// cloud-control-plane operation).
    pub reconfig_alpha: f64,
}

impl CostParams {
    /// testbed2: IBM Minsky, P100 + NVLink, IB CX-5 (§7).
    pub fn minsky() -> Self {
        Self {
            alpha_net: 1.3e-6,
            beta_net: 1.0 / 12.5e9,
            beta_ps: 1.0 / 1.0e9,
            ps_incast: 0.4,
            beta_hostmem: 1.0 / 38.4e9,
            gamma_host: 1.0 / 3.0e9,
            gamma_omp: 1.0 / 19.2e9,
            gamma_gpu_ibm: 1.0 / 30.0e9,
            gamma_gpu_nccl: 1.0 / 12.0e9,
            beta_gpu_bcast: 1.0 / 28.0e9,
            beta_h2d: 1.0 / 16.0e9, // PCIe-class staging copy
            gpu_sync: 20e-6,
            gpus_per_worker: 2,
            alpha_dev: 1.0e-6,
            beta_dev: 1.0 / 40.0e9, // NVLink-class device fabric
            devices: 1,
            gamma_codec: 1.0 / 8.0e9,
            hd_contention: 0.3,
            pipeline_chunks: 4,
            reconfig_alpha: 0.25,
        }
    }

    /// testbed1: Power8 + Kepler, IB CX-4 (§7). Older GPUs: slower device
    /// math and PCIe attach instead of NVLink.
    pub fn testbed1() -> Self {
        Self {
            alpha_net: 1.5e-6,
            beta_net: 1.0 / 12.5e9,
            beta_ps: 1.0 / 1.0e9,
            ps_incast: 0.5,
            beta_hostmem: 1.0 / 25.6e9,
            gamma_host: 1.0 / 3.0e9,
            gamma_omp: 1.0 / 12.8e9,
            gamma_gpu_ibm: 1.0 / 10.0e9,
            gamma_gpu_nccl: 1.0 / 6.0e9,
            beta_gpu_bcast: 1.0 / 10.0e9,
            beta_h2d: 1.0 / 10.0e9,
            gpu_sync: 25e-6,
            gpus_per_worker: 2,
            alpha_dev: 1.2e-6,
            beta_dev: 1.0 / 25.6e9, // host-shared-memory-class fabric
            devices: 1,
            gamma_codec: 1.0 / 5.0e9,
            hd_contention: 0.35,
            pipeline_chunks: 4,
            reconfig_alpha: 0.25,
        }
    }

    /// Virtual seconds a membership epoch stalls the ranks it touches:
    /// the fixed rebuild cost, a dissemination barrier over the `p` live
    /// ranks, and — when a joiner must bootstrap — moving
    /// `bootstrap_bytes` of checkpoint either from the PS (one pull over
    /// the TCP-class transport) or, serverless, by peer broadcast over
    /// the MPI fabric.
    pub fn reconfig_seconds(&self, p: usize, bootstrap_bytes: usize, servers: usize) -> f64 {
        let p = p.max(2) as f64;
        let rounds = p.log2().ceil();
        let barrier = 2.0 * rounds * self.alpha_net;
        let bootstrap = if bootstrap_bytes == 0 {
            0.0
        } else if servers > 0 {
            self.alpha_net + bootstrap_bytes as f64 * self.beta_ps
        } else {
            rounds * self.alpha_net + bootstrap_bytes as f64 * self.beta_net
        };
        self.reconfig_alpha + barrier + bootstrap
    }

    /// Price this parameter set on a fabric shared by `tenants` co-located
    /// jobs (the cluster authority's contention model, ISSUE 9): the
    /// inter-node bandwidth terms — MPI verbs (`beta_net`) and the
    /// TCP-class PS transport (`beta_ps`) — are partitioned `tenants`
    /// ways, so each job sees 1/t of the shared links. Per-message latency
    /// (`alpha_net`) and everything intra-node (device fabric, host
    /// memory, GPU paths) are unshared and unchanged; `tenants <= 1` is
    /// the identity.
    pub fn contended(&self, tenants: usize) -> Self {
        let t = tenants.max(1) as f64;
        let mut p = self.clone();
        p.beta_net *= t;
        p.beta_ps *= t;
        p
    }
}

// ---------------------------------------------------------------------------
// Links and contention
// ---------------------------------------------------------------------------

/// A serialized network link: one transfer at a time, FIFO.
///
/// This is the contention model: concurrent transfers queue, so k workers
/// pushing to one PS ingress link take ~k times as long — the §2.3 hot spot.
#[derive(Debug, Clone)]
pub struct Link {
    pub alpha: f64,
    pub beta: f64,
    /// Incast coefficient: queued flows inflate per-byte cost (TCP fan-in
    /// collapse). 0 for RDMA/verbs links.
    pub incast: f64,
    /// Congestion depth saturates here (at most `fan_in - 1` flows can
    /// actually share the link).
    pub incast_cap: u64,
    busy_until: VTime,
    /// Consecutive transfers that found the link busy (congestion depth).
    depth: u64,
    /// Total bytes ever moved (for utilization reporting).
    pub bytes_moved: u64,
}

impl Link {
    pub fn new(alpha: f64, beta: f64) -> Self {
        Self {
            alpha,
            beta,
            incast: 0.0,
            incast_cap: 0,
            busy_until: 0.0,
            depth: 0,
            bytes_moved: 0,
        }
    }

    pub fn with_incast(alpha: f64, beta: f64, incast: f64, cap: u64) -> Self {
        Self { incast, incast_cap: cap, ..Self::new(alpha, beta) }
    }

    /// Per-byte cost for a transfer requested at `now`: if the link is
    /// already busy the flow joins an incast fan-in and goodput degrades.
    fn effective_beta(&mut self, now: VTime) -> f64 {
        if self.busy_until > now {
            self.depth = (self.depth + 1).min(self.incast_cap);
        } else {
            self.depth = 0;
        }
        self.beta * (1.0 + self.incast * self.depth as f64)
    }

    /// Schedule a transfer of `bytes` requested at `now`; returns finish time.
    pub fn transfer(&mut self, now: VTime, bytes: usize) -> VTime {
        let beta = self.effective_beta(now);
        let start = now.max(self.busy_until);
        let finish = start + self.alpha + bytes as f64 * beta;
        self.busy_until = finish;
        self.bytes_moved += bytes as u64;
        finish
    }

    /// Time the link frees up.
    pub fn busy_until(&self) -> VTime {
        self.busy_until
    }

    pub fn reset(&mut self) {
        self.busy_until = 0.0;
        self.depth = 0;
        self.bytes_moved = 0;
    }
}

/// Cut-through transfer across a two-link path (worker NIC -> server
/// ingress): the flow occupies *both* links for the duration, paced by the
/// slower one. Avoids the store-and-forward double-count a naive
/// link-by-link model would charge.
pub fn path_transfer(a: &mut Link, b: &mut Link, now: VTime, bytes: usize) -> VTime {
    let beta = a.effective_beta(now).max(b.effective_beta(now));
    let start = now.max(a.busy_until).max(b.busy_until);
    let finish = start + a.alpha + b.alpha + bytes as f64 * beta;
    a.busy_until = finish;
    a.bytes_moved += bytes as u64;
    b.busy_until = finish;
    b.bytes_moved += bytes as u64;
    finish
}

/// The PS-side fabric: per-server ingress/egress links shared by all
/// workers, per-worker NICs. Keys are sharded across servers (MXNET shards
/// the KVStore), so a full push touches every server.
#[derive(Debug, Clone)]
pub struct PsFabric {
    pub server_in: Vec<Link>,
    pub server_out: Vec<Link>,
    pub worker_nic: Vec<Link>,
    pub params: CostParams,
}

impl PsFabric {
    pub fn new(n_servers: usize, n_workers: usize, params: CostParams) -> Self {
        // PS traffic rides the TCP-class transport, not MPI verbs; the
        // shared server links suffer incast under fan-in.
        let cap = n_workers.saturating_sub(1) as u64;
        let mk_srv =
            || Link::with_incast(params.alpha_net, params.beta_ps, params.ps_incast, cap);
        let mk_nic = || Link::new(params.alpha_net, params.beta_ps);
        Self {
            server_in: (0..n_servers).map(|_| mk_srv()).collect(),
            server_out: (0..n_servers).map(|_| mk_srv()).collect(),
            worker_nic: (0..n_workers).map(|_| mk_nic()).collect(),
            params,
        }
    }

    /// `bytes` split across `n` key shards: the division remainder is
    /// folded into the last shard so the modeled traffic conserves the
    /// requested bytes exactly (plain `bytes / n` silently dropped up to
    /// `n - 1` bytes per transfer, under-counting every push/pull).
    fn shard_bytes(bytes: usize, n: usize, i: usize) -> usize {
        let base = bytes / n;
        if i == n - 1 {
            base + bytes % n
        } else {
            base
        }
    }

    /// Worker `w` pushes `bytes` split evenly across all servers at `now`.
    /// Returns completion time (all shards delivered).
    ///
    /// Each shard flows cut-through over (worker NIC, server ingress); the
    /// per-server ingress link serializes across workers — the §2.3 hot
    /// spot.
    pub fn push(&mut self, now: VTime, w: usize, bytes: usize) -> VTime {
        let n = self.server_in.len().max(1);
        let mut done = now;
        for (i, s) in self.server_in.iter_mut().enumerate() {
            let shard = Self::shard_bytes(bytes, n, i);
            let t = path_transfer(&mut self.worker_nic[w], s, now, shard);
            done = done.max(t);
        }
        done
    }

    /// Worker `w` pulls `bytes` split across servers at `now`.
    pub fn pull(&mut self, now: VTime, w: usize, bytes: usize) -> VTime {
        let n = self.server_out.len().max(1);
        let mut done = now;
        for (i, s) in self.server_out.iter_mut().enumerate() {
            let shard = Self::shard_bytes(bytes, n, i);
            let t = path_transfer(s, &mut self.worker_nic[w], now, shard);
            done = done.max(t);
        }
        done
    }

    pub fn reset(&mut self) {
        for l in self
            .server_in
            .iter_mut()
            .chain(self.server_out.iter_mut())
            .chain(self.worker_nic.iter_mut())
        {
            l.reset();
        }
    }
}

// ---------------------------------------------------------------------------
// Discrete-event queue (used by the virtual-time trainer)
// ---------------------------------------------------------------------------

/// Min-heap event queue keyed by virtual time.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Ev<E>>,
    seq: u64,
}

#[derive(Debug)]
struct Ev<E> {
    at: VTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Ev<E> {
    fn eq(&self, o: &Self) -> bool {
        self.at == o.at && self.seq == o.seq
    }
}
impl<E> Eq for Ev<E> {}
impl<E> PartialOrd for Ev<E> {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl<E> Ord for Ev<E> {
    fn cmp(&self, o: &Self) -> Ordering {
        // Reverse for min-heap; break time ties by insertion order so the
        // simulation is fully deterministic.
        o.at.total_cmp(&self.at).then(o.seq.cmp(&self.seq))
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn push(&mut self, at: VTime, payload: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Ev { at, seq, payload });
    }

    pub fn pop(&mut self) -> Option<(VTime, E)> {
        self.heap.pop().map(|e| (e.at, e.payload))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_serializes_transfers() {
        let mut l = Link::new(1e-6, 1e-9); // 1 GB/s
        let t1 = l.transfer(0.0, 1_000_000); // 1 ms + 1 us
        let t2 = l.transfer(0.0, 1_000_000); // queued behind t1
        assert!((t1 - 1.001e-3).abs() < 1e-12);
        assert!((t2 - 2.002e-3).abs() < 1e-12);
        assert_eq!(l.bytes_moved, 2_000_000);
    }

    #[test]
    fn link_idle_gap_not_backfilled() {
        let mut l = Link::new(0.0, 1e-9);
        let t1 = l.transfer(0.0, 1000);
        let t2 = l.transfer(1.0, 1000); // arrives after idle gap
        assert!(t1 < 1.0);
        assert!((t2 - 1.000001).abs() < 1e-9);
    }

    #[test]
    fn ps_fabric_hot_spot_scales_superlinearly() {
        // k workers pushing simultaneously to 1 server: serialization on
        // the ingress + TCP incast collapse make the last push finish
        // *worse* than k x the solo time (the §2.3 hot spot).
        let p = CostParams::testbed1();
        let bytes = 10 << 20;
        let mut f1 = PsFabric::new(1, 1, p.clone());
        let solo = f1.push(0.0, 0, bytes);
        let mut f12 = PsFabric::new(1, 12, p);
        let mut last = 0.0f64;
        for w in 0..12 {
            last = last.max(f12.push(0.0, w, bytes));
        }
        let ratio = last / solo;
        assert!(ratio > 12.0 && ratio < 60.0, "ratio {ratio}");
    }

    #[test]
    fn incast_depth_saturates_under_sustained_load() {
        // Continuous traffic must reach a steady per-transfer cost, not
        // diverge (the cap = fan-in - 1).
        let mut l = Link::with_incast(0.0, 1e-9, 0.5, 3);
        let mut prev_finish = 0.0f64;
        let mut prev_cost = 0.0f64;
        for i in 0..50 {
            let fin = l.transfer(0.0, 1_000_000); // permanently congested
            let cost = fin - prev_finish;
            if i > 10 {
                assert!((cost - prev_cost).abs() < 1e-12, "diverging at {i}");
            }
            prev_cost = cost;
            prev_finish = fin;
        }
        // Steady multiplier = 1 + 0.5 * 3.
        assert!((prev_cost - 2.5e-3).abs() < 1e-9, "{prev_cost}");
    }

    #[test]
    fn ps_fabric_conserves_bytes_across_shards() {
        // Sum of modeled shard bytes == requested bytes, even when the
        // server count does not divide the transfer (the old integer
        // division silently dropped up to n_servers - 1 bytes).
        for servers in [1usize, 2, 3, 5, 7] {
            for bytes in [0usize, 1, 100, 1000 + 3, (10 << 20) + servers - 1] {
                let mut f = PsFabric::new(servers, 2, CostParams::testbed1());
                f.push(0.0, 0, bytes);
                let pushed: u64 = f.server_in.iter().map(|l| l.bytes_moved).sum();
                assert_eq!(pushed, bytes as u64, "push servers={servers} bytes={bytes}");
                assert_eq!(f.worker_nic[0].bytes_moved, bytes as u64);
                f.pull(0.0, 1, bytes);
                let pulled: u64 = f.server_out.iter().map(|l| l.bytes_moved).sum();
                assert_eq!(pulled, bytes as u64, "pull servers={servers} bytes={bytes}");
                assert_eq!(f.worker_nic[1].bytes_moved, bytes as u64);
            }
        }
    }

    #[test]
    fn more_servers_relieve_contention() {
        let p = CostParams::testbed1();
        let bytes = 10 << 20;
        let run = |servers: usize| {
            let mut f = PsFabric::new(servers, 12, p.clone());
            let mut last = 0.0f64;
            for w in 0..12 {
                last = last.max(f.push(0.0, w, bytes));
            }
            last
        };
        assert!(run(4) < run(2));
        assert!(run(2) < run(1));
    }

    #[test]
    fn event_queue_orders_by_time_then_seq() {
        let mut q = EventQueue::new();
        q.push(2.0, "b");
        q.push(1.0, "a");
        q.push(2.0, "c");
        assert_eq!(q.pop().unwrap().1, "a");
        assert_eq!(q.pop().unwrap().1, "b"); // same time: FIFO by seq
        assert_eq!(q.pop().unwrap().1, "c");
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn reconfig_cost_scales_with_bootstrap_and_degrades_gracefully() {
        let p = CostParams::testbed1();
        let plain = p.reconfig_seconds(12, 0, 2);
        // Dominated by the fixed control-plane cost, sub-second scale.
        assert!(plain >= p.reconfig_alpha && plain < p.reconfig_alpha + 0.01);
        // A joiner's checkpoint pull prices real bytes over the PS...
        let with_join = p.reconfig_seconds(12, 102 << 20, 2);
        assert!(with_join > plain + 0.05, "{with_join} vs {plain}");
        // ...and the serverless peer bcast rides the faster MPI fabric.
        let serverless = p.reconfig_seconds(12, 102 << 20, 0);
        assert!(serverless < with_join);
        assert!(serverless > plain);
    }

    #[test]
    fn contended_scales_only_shared_wire_bandwidth() {
        let p = CostParams::testbed1();
        let c1 = p.contended(1);
        assert_eq!(c1.beta_net, p.beta_net);
        assert_eq!(c1.beta_ps, p.beta_ps);
        let c3 = p.contended(3);
        assert_eq!(c3.beta_net, 3.0 * p.beta_net);
        assert_eq!(c3.beta_ps, 3.0 * p.beta_ps);
        // Latency and intra-node terms are per-job resources: unchanged.
        assert_eq!(c3.alpha_net, p.alpha_net);
        assert_eq!(c3.beta_dev, p.beta_dev);
        assert_eq!(c3.gamma_host, p.gamma_host);
        // tenants=0 clamps to the identity, not to a free fabric.
        assert_eq!(p.contended(0).beta_net, p.beta_net);
    }

    #[test]
    fn cost_presets_sane() {
        for p in [CostParams::minsky(), CostParams::testbed1()] {
            assert!(p.alpha_net > 0.0 && p.beta_net > 0.0);
            // GPU reduce faster than single-thread host reduce.
            assert!(p.gamma_gpu_ibm < p.gamma_host);
        }
        // Paper: IBMGpu reduce 30 GB/s ~ 2.5x NCCL's 12 GB/s.
        let m = CostParams::minsky();
        let r = m.gamma_gpu_nccl / m.gamma_gpu_ibm;
        assert!(r > 2.0 && r < 3.0);
    }
}
