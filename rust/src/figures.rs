//! Drivers that regenerate every table and figure of the paper's §7.
//!
//! Each `figNN` function returns the data series and (optionally) writes a
//! tidy CSV under `results/`. Convergence figures (11/13/14/16) run the
//! virtual-time trainer with real PJRT numerics; collective figures
//! (15/17–20) evaluate the §6 cost models. The `examples/` binaries and
//! the bench harness are thin wrappers around these.

use crate::collectives::sim::{
    network_allreduce_seconds, simulate as csim, tier_wire_bytes, Design, SimResult,
};
use crate::collectives::AlgoKind;
use crate::compress::Compressor;
use crate::config::{Algo, ExperimentConfig};
use crate::metrics::{write_runs_csv, RunResult, Table};
use crate::netsim::CostParams;
use anyhow::Result;
use std::path::Path;

/// Shared testbed1 configuration for the convergence figures (Figs 11–14):
/// 12 workers, 2 servers, 2 MPI clients, ResNet-analog model.
pub fn fig_base(algo: Algo, epochs: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::testbed1(algo);
    cfg.epochs = epochs;
    cfg
}

fn run_modes(
    algos: &[Algo],
    epochs: usize,
    artifacts: &Path,
    tweak: impl Fn(&mut ExperimentConfig),
) -> Result<Vec<RunResult>> {
    let mut runs = Vec::new();
    for &algo in algos {
        let mut cfg = fig_base(algo, epochs);
        tweak(&mut cfg);
        eprintln!("[fig] running {} ({} epochs)...", algo.name(), cfg.epochs);
        runs.push(crate::trainer::sim::simulate(&cfg, artifacts)?);
    }
    Ok(runs)
}

/// Render acc-vs-time series the way the paper plots them.
pub fn print_acc_vs_time(title: &str, runs: &[RunResult]) {
    println!("== {title} ==");
    let mut t = Table::new(&["mode", "epoch", "vtime_s", "val_acc", "train_loss"]);
    for run in runs {
        for r in &run.records {
            t.row(vec![
                run.label.clone(),
                r.epoch.to_string(),
                format!("{:.1}", r.vtime),
                format!("{:.3}", r.val_acc),
                format!("{:.3}", r.train_loss),
            ]);
        }
    }
    println!("{}", t.render());
}

/// Fig. 11: validation accuracy vs time, dist-vs-mpi {SGD, ASGD}.
pub fn fig11(artifacts: &Path, out_dir: &Path, epochs: usize) -> Result<Vec<RunResult>> {
    let runs = run_modes(
        &[
            Algo::named("dist-SGD"),
            Algo::named("mpi-SGD"),
            Algo::named("dist-ASGD"),
            Algo::named("mpi-ASGD"),
        ],
        epochs,
        artifacts,
        |_| {},
    )?;
    write_runs_csv(&out_dir.join("fig11_sgd_asgd.csv"), &runs)?;
    Ok(runs)
}

/// Fig. 12: average epoch time (seconds) for all six paper modes. The
/// sweep is derived from the registry (`paper_mode` entries, dist block
/// first), so the CSV regenerates identically while new registered
/// algorithms stay out of the paper figure.
pub fn fig12(artifacts: &Path, out_dir: &Path, epochs: usize) -> Result<Vec<(String, f64)>> {
    let runs = run_modes(&Algo::paper_modes(), epochs, artifacts, |_| {})?;
    let bars: Vec<(String, f64)> = runs
        .iter()
        .map(|r| (r.label.clone(), r.avg_epoch_time))
        .collect();
    let mut csv = crate::metrics::Csv::create(
        &out_dir.join("fig12_epoch_time.csv"),
        "mode,avg_epoch_time_s",
    )?;
    for (label, t) in &bars {
        csv.row(&[label.clone(), format!("{t:.3}")])?;
    }
    write_runs_csv(&out_dir.join("fig12_runs.csv"), &runs)?;
    Ok(bars)
}

/// Fig. 13: ESGD family — mpi-ESGD vs dist-ESGD vs mpi-SGD vs mpi-ASGD.
pub fn fig13(artifacts: &Path, out_dir: &Path, epochs: usize) -> Result<Vec<RunResult>> {
    let runs = run_modes(
        &[
            Algo::named("mpi-ESGD"),
            Algo::named("dist-ESGD"),
            Algo::named("mpi-SGD"),
            Algo::named("mpi-ASGD"),
        ],
        epochs,
        artifacts,
        |_| {},
    )?;
    write_runs_csv(&out_dir.join("fig13_esgd.csv"), &runs)?;
    Ok(runs)
}

/// Fig. 14: multi-epoch run, mpi-ESGD vs mpi-SGD (paper reaches 0.67).
pub fn fig14(artifacts: &Path, out_dir: &Path, epochs: usize) -> Result<Vec<RunResult>> {
    let runs = run_modes(
        &[Algo::named("mpi-ESGD"), Algo::named("mpi-SGD")],
        epochs,
        artifacts,
        |_| {},
    )?;
    write_runs_csv(&out_dir.join("fig14_esgd_epochs.csv"), &runs)?;
    Ok(runs)
}

/// Fig. 16: learning curve in the pure-MPI configuration of testbed2
/// (#servers = 0, mpi-SGD over one client of all workers).
pub fn fig16(artifacts: &Path, out_dir: &Path, epochs: usize) -> Result<Vec<RunResult>> {
    let runs = run_modes(&[Algo::named("mpi-SGD")], epochs, artifacts, |cfg| {
        cfg.servers = 0;
        cfg.clients = 1;
        cfg.testbed = "minsky".into();
        // Larger effective batch => larger lr (paper: 0.5 instead of 0.1).
        cfg.lr *= 2.0;
    })?;
    write_runs_csv(&out_dir.join("fig16_learning_curve.csv"), &runs)?;
    Ok(runs)
}

/// Convergence under churn: the §2 elasticity argument made measurable.
///
/// Three testbed1 runs share one fault plan — a worker killed mid-run plus
/// a straggler — and differ only in how the membership epoch hits them:
///
/// * `mpi-SGD (hybrid)` — sync MPI clients under a PS: the kill is a
///   *global* membership barrier (every world rebuilds), then training
///   continues renormalized.
/// * `mpi-SGD (pure)` — `#servers == 0`, one client of all workers: same
///   global stall, and until the epoch fires the straggler gates every
///   lockstep round — the paper's "pure MPI stalls" half.
/// * `mpi-ESGD (hybrid)` — only the churned client pays the stall; the
///   others keep training against the PS centers, so the loss keeps
///   improving *through* the event — the "degrades gracefully" half.
///
/// The kill lands mid-run (half the iteration budget); CSV:
/// `fig_churn.csv`.
pub fn fig_churn(artifacts: &Path, out_dir: &Path, epochs: usize) -> Result<Vec<RunResult>> {
    let base = fig_base(Algo::named("mpi-SGD"), epochs);
    let iters_per_epoch =
        (base.samples_per_epoch / (base.workers as u64 * base.batch as u64)).max(1);
    // Mid-run kill, earlier straggle; both clear of the final ESGD
    // interval boundary even at epochs == 1.
    let kill_at = (iters_per_epoch * epochs as u64 / 2).max(1);
    let straggle_at = (kill_at / 2).max(1);
    let fault = format!("kill:11@{kill_at},straggle:1@{straggle_at}x3");

    let mut runs = Vec::new();
    for (algo, servers, clients, tag) in [
        (Algo::named("mpi-SGD"), 2usize, 2usize, "hybrid"),
        (Algo::named("mpi-SGD"), 0, 1, "pure"),
        (Algo::named("mpi-ESGD"), 2, 2, "hybrid"),
    ] {
        let mut cfg = fig_base(algo, epochs);
        cfg.servers = servers;
        cfg.clients = clients;
        cfg.fault = fault.clone();
        eprintln!(
            "[fig] running {} ({tag}, fault {fault}, {} epochs)...",
            algo.name(),
            cfg.epochs
        );
        let mut run = crate::trainer::sim::simulate(&cfg, artifacts)?;
        run.label = format!("{} ({tag}+churn)", run.label);
        runs.push(run);
    }
    write_runs_csv(&out_dir.join("fig_churn.csv"), &runs)?;
    Ok(runs)
}

/// Accuracy vs virtual time under gradient compression: one mpi-SGD run
/// per registered codec (`identity` / `int8` / `topk`, registry-derived so
/// a new codec appears here automatically), identical in everything but
/// the compression knob. The identity curve is bitwise the plain mpi-SGD
/// run; lossy codecs shift the time axis by the wire-byte savings on the
/// PS path (minus their codec γ) and the accuracy axis by whatever the
/// error-feedback round-trip costs convergence. CSV: `fig_compress.csv`.
pub fn fig_compress(artifacts: &Path, out_dir: &Path, epochs: usize) -> Result<Vec<RunResult>> {
    let mut runs = Vec::new();
    for codec in crate::compress::Codec::all() {
        let mut cfg = fig_base(Algo::named("mpi-SGD"), epochs);
        cfg.compression = codec.name().into();
        let wire_mb = cfg.build_compressor().wire_bytes(cfg.virtual_model_bytes / 4) as f64
            / (1 << 20) as f64;
        eprintln!(
            "[fig] running mpi-SGD [{}] ({} epochs, {wire_mb:.1} MB/push on the wire)...",
            codec.name(),
            cfg.epochs
        );
        let mut run = crate::trainer::sim::simulate(&cfg, artifacts)?;
        run.label = format!("mpi-SGD [{}]", codec.name());
        runs.push(run);
    }
    write_runs_csv(&out_dir.join("fig_compress.csv"), &runs)?;
    Ok(runs)
}

// ---------------------------------------------------------------------------
// Cost-model figures (no artifacts needed)
// ---------------------------------------------------------------------------

/// Figs 17–19: tensor-allreduce bandwidth for the four §7.3 designs at a
/// given message size, swept over worker count.
pub fn fig17_19(bytes: usize, out_dir: Option<&Path>) -> Result<Vec<SimResult>> {
    let params = CostParams::minsky();
    let designs = [
        Design::RingIbm { rings: 2 },
        Design::RingNccl,
        Design::OmpRing,
        Design::Reg,
    ];
    let mut rows = Vec::new();
    for p in [2usize, 4, 8, 16, 32] {
        for d in designs {
            rows.push(csim(d, p, bytes, &params));
        }
    }
    if let Some(dir) = out_dir {
        let mb = bytes >> 20;
        let mut csv = crate::metrics::Csv::create(
            &dir.join(format!("fig17_19_allreduce_{mb}MB.csv")),
            "design,workers,bytes,seconds,gbps",
        )?;
        for r in &rows {
            csv.row(&[
                r.design_label.clone(),
                r.p.to_string(),
                r.bytes.to_string(),
                format!("{:.6}", r.seconds),
                format!("{:.3}", r.gbps),
            ])?;
        }
    }
    Ok(rows)
}

/// Fig. 20: IBM node-tensor ring vs Baidu every-GPU ring, same GPU count.
pub fn fig20(out_dir: Option<&Path>) -> Result<Vec<(usize, f64, f64, f64)>> {
    let params = CostParams::minsky();
    let p = 16; // 16 workers x 2 GPUs = 32 GPUs
    let mut rows = Vec::new();
    for mb in [1usize, 4, 16, 64, 128] {
        let bytes = mb << 20;
        let ibm = csim(Design::RingIbm { rings: 2 }, p, bytes, &params);
        let baidu = csim(Design::BaiduRing, p, bytes, &params);
        rows.push((mb, ibm.seconds, baidu.seconds, baidu.seconds / ibm.seconds));
    }
    if let Some(dir) = out_dir {
        let mut csv = crate::metrics::Csv::create(
            &dir.join("fig20_baidu.csv"),
            "mb,ibm_ring_s,baidu_ring_s,factor",
        )?;
        for (mb, i, b, f) in &rows {
            csv.row(&[mb.to_string(), format!("{i:.6}"), format!("{b:.6}"), format!("{f:.2}")])?;
        }
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// fig_twotier: the ISSUE-8 device-tier payoff figure
// ---------------------------------------------------------------------------

/// One `fig_twotier` data point: flat vs two-tier at one
/// (strategy, codec, devices) cell of the matrix.
#[derive(Debug, Clone)]
pub struct TwotierRow {
    pub strategy: String,
    pub codec: String,
    /// Devices per node (k).
    pub devices: usize,
    /// Modeled epoch seconds with every device rank on the wire (flat).
    pub flat_epoch_s: f64,
    /// Modeled epoch seconds with the intra-node tier reducing first.
    pub two_tier_epoch_s: f64,
    /// Per-node per-epoch bytes moved on the device fabric (flat: 0).
    pub flat_intra_bytes: u64,
    /// Per-node per-epoch bytes through the NIC under the flat schedule.
    pub flat_inter_bytes: u64,
    pub two_tier_intra_bytes: u64,
    /// Exactly `flat_inter_bytes / devices` — the ISSUE-8 CI-gated ratio.
    pub two_tier_inter_bytes: u64,
}

/// α-β-γ cost of one EF-compressed allgather-reduce of `dense_bytes`
/// across `p` ranks whose NICs are shared `contention`-way: one encode,
/// a (p−1)-step allgather of the codec's wire bytes, decode+fold of every
/// peer payload, one dense seat — the network portion of
/// [`crate::collectives::compressed_allreduce`] without the GPU staging
/// phases (identical in both arms, so they cancel out of the comparison).
fn lossy_allgather_seconds(
    p: usize,
    dense_bytes: usize,
    codec: &dyn Compressor,
    contention: usize,
    params: &CostParams,
) -> f64 {
    let n = dense_bytes as f64;
    let wire = codec.wire_bytes(dense_bytes / 4) as f64;
    let encode = n * params.gamma_codec;
    let seat = n * params.gamma_omp + wire * params.gamma_codec;
    if p <= 1 {
        return encode + seat;
    }
    let pf = p as f64;
    let b = params.beta_net * contention.max(1) as f64;
    let net = (pf - 1.0) * (params.alpha_net + wire * b);
    let fold = (pf - 1.0) * wire * (params.gamma_codec + params.gamma_omp);
    encode + seat + net + fold
}

/// The intra-node leg of a *compressed* two-tier reduction: `devices − 1`
/// member payloads move coded over the device fabric (gather + broadcast
/// back), each paying one leader-side decode plus a dense fold — the cost
/// model of `KvWorker::local_merge`'s per-device EF round-trips.
fn twotier_intra_lossy_seconds(
    devices: usize,
    dense_bytes: usize,
    codec: &dyn Compressor,
    params: &CostParams,
) -> f64 {
    let n = dense_bytes as f64;
    let wire = codec.wire_bytes(dense_bytes / 4) as f64;
    devices.saturating_sub(1) as f64
        * (2.0 * (params.alpha_dev + wire * params.beta_dev)
            + wire * params.gamma_codec
            + n * params.gamma_omp)
}

/// The ISSUE-8 payoff figure: modeled epoch time and per-tier wire bytes,
/// flat vs two-tier, as the per-node device count k sweeps {1, 2, 4, 8}
/// over a strategy × codec matrix at transformer_tiny scale (~1M-param
/// f32 gradient payload). Per-device batch is b/k in *both* arms, so
/// compute is identical and the comparison isolates the communication
/// plane: flat puts every device rank's traffic through its node's shared
/// NIC (k-way `beta_net` contention, best flat schedule per cell), while
/// two-tier reduces the k device buffers on the NVLink-class fabric first
/// and sends one leader stream per node. `mpi-ESGD` syncs every
/// `interval` (8) iterations instead of every iteration, scaling both
/// arms' comm alike. CSV: `fig_twotier.csv`.
pub fn fig_twotier(out_dir: Option<&Path>) -> Result<Vec<TwotierRow>> {
    const NODES: usize = 4;
    // transformer_tiny-scale payload: ~1M f32 parameters.
    const BYTES: usize = 4 << 20;
    const ITERS: u64 = 96;
    // Per-device fwd+bwd seconds at the full per-worker batch (k = 1).
    const COMPUTE_S: f64 = 0.05;
    const TOPK_RATIO: f64 = 0.05;
    const ESGD_INTERVAL: u64 = 8;
    let params = CostParams::minsky();
    let strategies: [(&str, u64); 3] =
        [("mpi-SGD", 1), ("mpi-ASGD", 1), ("mpi-ESGD", ESGD_INTERVAL)];
    let mut rows = Vec::new();
    for (strategy, sync_every) in strategies {
        for codec in crate::compress::Codec::all() {
            let boxed = codec.build(TOPK_RATIO);
            for k in [1usize, 2, 4, 8] {
                let p = NODES * k;
                let mut pk = params.clone();
                pk.devices = k;
                let (flat_comm, tt_comm) = if boxed.is_identity() {
                    // Flat gets its best schedule per cell; two-tier is
                    // priced by the same α-β-γ model (contended flat legs,
                    // uncontended leader ring).
                    let flat = [AlgoKind::Ring, AlgoKind::HalvingDoubling, AlgoKind::Hierarchical]
                        .into_iter()
                        .map(|kind| network_allreduce_seconds(kind, p, BYTES, &pk))
                        .fold(f64::INFINITY, f64::min);
                    (flat, network_allreduce_seconds(AlgoKind::TwoTier, p, BYTES, &pk))
                } else {
                    let flat = lossy_allgather_seconds(p, BYTES, boxed.as_ref(), k, &params);
                    let tt = twotier_intra_lossy_seconds(k, BYTES, boxed.as_ref(), &params)
                        + lossy_allgather_seconds(NODES, BYTES, boxed.as_ref(), 1, &params);
                    (flat, tt)
                };
                let syncs = ITERS / sync_every;
                let compute = ITERS as f64 * COMPUTE_S / k as f64;
                let payload = if boxed.is_identity() {
                    BYTES
                } else {
                    boxed.wire_bytes(BYTES / 4)
                };
                let (fi, fe) = tier_wire_bytes(false, k, payload);
                let (ti, te) = tier_wire_bytes(true, k, payload);
                rows.push(TwotierRow {
                    strategy: strategy.to_string(),
                    codec: codec.name().to_string(),
                    devices: k,
                    flat_epoch_s: compute + syncs as f64 * flat_comm,
                    two_tier_epoch_s: compute + syncs as f64 * tt_comm,
                    flat_intra_bytes: fi * syncs,
                    flat_inter_bytes: fe * syncs,
                    two_tier_intra_bytes: ti * syncs,
                    two_tier_inter_bytes: te * syncs,
                });
            }
        }
    }
    if let Some(dir) = out_dir {
        let mut csv = crate::metrics::Csv::create(
            &dir.join("fig_twotier.csv"),
            "strategy,codec,devices,flat_epoch_s,two_tier_epoch_s,\
             flat_intra_bytes,flat_inter_bytes,two_tier_intra_bytes,two_tier_inter_bytes",
        )?;
        for r in &rows {
            csv.row(&[
                r.strategy.clone(),
                r.codec.clone(),
                r.devices.to_string(),
                format!("{:.6}", r.flat_epoch_s),
                format!("{:.6}", r.two_tier_epoch_s),
                r.flat_intra_bytes.to_string(),
                r.flat_inter_bytes.to_string(),
                r.two_tier_intra_bytes.to_string(),
                r.two_tier_inter_bytes.to_string(),
            ])?;
        }
    }
    Ok(rows)
}

/// One Fig. 15 data point: virtual epoch seconds for ResNet-50-scale
/// training at `nodes` Minsky nodes (2 workers/node), pure MPI.
///
/// `overlap` prices the DAG-embedded collective path (arXiv:1802.06949):
/// each bucketed message is issued as its gradients emerge from backward,
/// so only the communication exceeding the overlap window is exposed. The
/// `reg` baseline (default blocking MPI_Allreduce) never overlaps.
fn fig15_epoch_time(
    nodes: usize,
    weak: bool,
    design: Design,
    overlap: bool,
    params: &CostParams,
) -> f64 {
    let p = nodes * 2; // workers (one per socket)
    let bytes = 102 << 20; // ResNet-50 f32 parameters
    let base_batch = 128.0;
    let compute_per_128 = 0.35; // s, P100-class fwd+bwd
    let samples = 1_281_167.0; // ImageNet-1K epoch
    let (batch, _global) = if weak {
        (base_batch, base_batch * p as f64)
    } else {
        // Strong scaling: global batch fixed at 32 workers' worth; the
        // per-worker batch halves as nodes double (§7.3).
        let global = base_batch * 8.0;
        ((global / p as f64).max(1.0), global)
    };
    let batches_per_worker = samples / (p as f64 * batch);
    let compute = compute_per_128 * batch / base_batch;
    // Gradients are aggregated per layer as the backward pass emits them
    // (§2.1): ResNet-50's ~100 tensors batched into ~32 bucketed
    // messages, each paying the collective's fixed costs.
    let n_msgs = 32;
    let ar = n_msgs as f64 * csim(design, p, bytes / n_msgs, params).seconds;
    let step = if overlap {
        crate::collectives::sim::overlapped_step_seconds(compute, ar, n_msgs)
    } else {
        compute + ar
    };
    batches_per_worker * step
}

/// Fig. 15: ResNet-50 scaling behaviour on testbed2 (strong vs weak
/// scaling, optimized ring vs the reg-IBMGpu baseline), epoch seconds vs
/// node count.
pub fn fig15(out_dir: Option<&Path>) -> Result<Vec<(usize, f64, f64, f64, f64)>> {
    let params = CostParams::minsky();
    let ring = Design::RingIbm { rings: 2 };
    let mut rows = Vec::new();
    for nodes in [2usize, 4, 8, 16, 32] {
        // The optimized ring runs DAG-embedded (overlapped); the reg
        // baseline is the default *blocking* MPI_Allreduce.
        let weak = fig15_epoch_time(nodes, true, ring, true, &params);
        let strong = fig15_epoch_time(nodes, false, ring, true, &params);
        let weak_reg = fig15_epoch_time(nodes, true, Design::Reg, false, &params);
        let strong_reg = fig15_epoch_time(nodes, false, Design::Reg, false, &params);
        rows.push((nodes, weak, strong, weak_reg, strong_reg));
    }
    if let Some(dir) = out_dir {
        let mut csv = crate::metrics::Csv::create(
            &dir.join("fig15_scaling.csv"),
            "nodes,weak_ring_s,strong_ring_s,weak_reg_s,strong_reg_s",
        )?;
        for (n, w, s, rw, rs) in &rows {
            csv.row(&[
                n.to_string(),
                format!("{w:.1}"),
                format!("{s:.1}"),
                format!("{rw:.1}"),
                format!("{rs:.1}"),
            ])?;
        }
    }
    Ok(rows)
}

/// One `fig_cluster` data point: the same scripted arrival plan at one
/// arrival rate, run under both allocation policies.
#[derive(Debug, Clone)]
pub struct ClusterRow {
    /// Seconds between consecutive job arrivals (smaller = higher rate).
    pub arrival_interval_s: f64,
    pub jobs: usize,
    pub pool_nodes: usize,
    pub static_makespan_s: f64,
    pub elastic_makespan_s: f64,
    /// Aggregate goodput: useful samples per second of cluster time.
    pub static_goodput: f64,
    pub elastic_goodput: f64,
    /// Total useful samples (identical under both policies by
    /// construction: the plan fixes every job's target).
    pub total_samples: u64,
    /// Pool-conservation witness folded over both runs: free + allocated
    /// at every audit snapshot. Both must equal `pool_nodes` exactly.
    pub alloc_free_min: usize,
    pub alloc_free_max: usize,
    /// Double-booking findings across both runs (must be 0).
    pub double_booked: usize,
}

/// The cluster figure: aggregate goodput vs job-arrival rate, static vs
/// elastic allocation, on a fixed heterogeneous workload (different
/// strategies, codecs and gang widths) over a shared 8-node pool. The
/// paper's cloud pitch (§1–§2) quantified: the elastic policy dominates
/// the static baseline at every rate and wins hardest under contention.
pub fn fig_cluster(out_dir: Option<&Path>) -> Result<Vec<ClusterRow>> {
    use crate::cluster::{AllocPolicy, ArrivalPlan, ClusterSpec};
    const POOL: usize = 8;
    // Heterogeneous five-job mix: sync/elastic strategies, int8/topk
    // codecs, 2- and 4-node gangs, one k=2 two-tier job.
    const SHAPES: [&str; 5] = [
        "mpi-SGD:2x6",
        "mpi-ESGD.int8:2x6",
        "mpi-SGD.topk:4x4",
        "mpi-SGD.identity.2:2x6",
        "mpi-ESGD:2x6",
    ];
    let mut rows = Vec::new();
    for interval in [240.0f64, 120.0, 60.0, 30.0, 10.0] {
        let plan_str: Vec<String> = SHAPES
            .iter()
            .enumerate()
            .map(|(i, s)| format!("{s}@{}", interval * i as f64))
            .collect();
        let plan = ArrivalPlan::parse(&plan_str.join(","))?;
        let st = crate::cluster::simulate(&ClusterSpec::with_defaults(
            POOL,
            AllocPolicy::Static,
            plan.clone(),
        ))?;
        let el = crate::cluster::simulate(&ClusterSpec::with_defaults(
            POOL,
            AllocPolicy::Elastic,
            plan,
        ))?;
        rows.push(ClusterRow {
            arrival_interval_s: interval,
            jobs: SHAPES.len(),
            pool_nodes: POOL,
            static_makespan_s: st.makespan_s,
            elastic_makespan_s: el.makespan_s,
            static_goodput: st.goodput(),
            elastic_goodput: el.goodput(),
            total_samples: st.total_samples,
            alloc_free_min: st.audit.alloc_free_min.min(el.audit.alloc_free_min),
            alloc_free_max: st.audit.alloc_free_max.max(el.audit.alloc_free_max),
            double_booked: st.audit.double_booked + el.audit.double_booked,
        });
    }
    if let Some(dir) = out_dir {
        let mut csv = crate::metrics::Csv::create(
            &dir.join("fig_cluster.csv"),
            "arrival_interval_s,jobs,pool_nodes,static_makespan_s,elastic_makespan_s,\
             static_goodput,elastic_goodput,total_samples,alloc_free_min,alloc_free_max,\
             double_booked",
        )?;
        for r in &rows {
            csv.row(&[
                format!("{:.0}", r.arrival_interval_s),
                r.jobs.to_string(),
                r.pool_nodes.to_string(),
                format!("{:.1}", r.static_makespan_s),
                format!("{:.1}", r.elastic_makespan_s),
                format!("{:.3}", r.static_goodput),
                format!("{:.3}", r.elastic_goodput),
                r.total_samples.to_string(),
                r.alloc_free_min.to_string(),
                r.alloc_free_max.to_string(),
                r.double_booked.to_string(),
            ])?;
        }
    }
    Ok(rows)
}

/// §7.3 intra-node table: tensor reduce/broadcast bandwidths (GB/s).
pub fn intranode_table() -> Vec<(&'static str, f64)> {
    let m = CostParams::minsky();
    vec![
        ("IBMGpu reduce -> host", 1e-9 / m.gamma_gpu_ibm),
        ("NCCL reduce (1 comm set)", 1e-9 / m.gamma_gpu_nccl),
        ("NCCL reduce (2 comm sets)", 1.25e-9 / m.gamma_gpu_nccl),
        ("broadcast host -> GPUs", 1e-9 / m.beta_gpu_bcast),
        ("host write BW bound", 1e-9 / m.beta_hostmem),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig_cluster_elastic_dominates_static() {
        // The PR 9 acceptance gate: elastic goodput >= static at every
        // swept arrival rate, strictly greater at the highest rate, with
        // the integer pool-conservation invariant intact throughout.
        let rows = fig_cluster(None).unwrap();
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert!(
                r.elastic_goodput >= r.static_goodput,
                "interval {}: elastic {} < static {}",
                r.arrival_interval_s,
                r.elastic_goodput,
                r.static_goodput
            );
            assert_eq!(r.alloc_free_min, r.pool_nodes, "interval {}", r.arrival_interval_s);
            assert_eq!(r.alloc_free_max, r.pool_nodes, "interval {}", r.arrival_interval_s);
            assert_eq!(r.double_booked, 0, "interval {}", r.arrival_interval_s);
            assert!(r.total_samples > 0);
        }
        // Rates are swept slowest-first: the last row is the most
        // contended cluster, where elasticity must win outright.
        let hot = rows.last().unwrap();
        assert!(
            hot.elastic_goodput > hot.static_goodput,
            "elastic does not strictly win at the highest rate: {} vs {}",
            hot.elastic_goodput,
            hot.static_goodput
        );
    }

    #[test]
    fn fig15_weak_scaling_flatter_than_strong() {
        let rows = fig15(None).unwrap();
        let (first, last) = (rows.first().unwrap(), rows.last().unwrap());
        let weak_growth = last.1 / first.1;
        let strong_growth = last.2 / first.2;
        // Weak scaling stays near-flat; strong scaling blows up in
        // comm-bound territory as the per-worker batch shrinks.
        assert!(weak_growth < 1.3, "weak grew {weak_growth}");
        assert!(strong_growth > weak_growth);
    }

    #[test]
    fn fig15_ring_beats_reg_about_2x_when_comm_bound() {
        // §7.3: "our optimizations are nearly twice as fast than using the
        // default, reg-IBMGpu approach" — visible in the strong-scaling
        // (communication-bound) regime at full machine scale. The DAG-
        // embedded ring additionally overlaps its communication with
        // backward compute (arXiv:1802.06949) while the blocking reg
        // baseline cannot, so the modeled gap now exceeds the paper's
        // blocking-vs-blocking 2x.
        let rows = fig15(None).unwrap();
        let (_, _, strong_ring, _, strong_reg) = rows.last().unwrap();
        let f = strong_reg / strong_ring;
        assert!(f > 1.4 && f < 8.0, "factor {f}");
    }

    #[test]
    fn fig17_19_ibm_wins_and_bandwidth_positive() {
        for bytes in [4 << 20, 16 << 20, 64 << 20] {
            let rows = fig17_19(bytes, None).unwrap();
            assert!(rows.iter().all(|r| r.gbps > 0.0));
            // At every worker count, ring-IBMGpu(2) has the max bandwidth.
            for p in [2usize, 4, 8, 16, 32] {
                let at_p: Vec<_> = rows.iter().filter(|r| r.p == p).collect();
                let best = at_p
                    .iter()
                    .max_by(|a, b| a.gbps.total_cmp(&b.gbps))
                    .unwrap();
                assert_eq!(best.design_label, "ring-IBMGpu(2)", "p={p} bytes={bytes}");
            }
        }
    }

    #[test]
    fn fig20_factor_in_paper_range() {
        let rows = fig20(None).unwrap();
        // Mid-size messages show the ~6x factor (3-10 accepted).
        let (_, _, _, f) = rows[2]; // 16 MB
        assert!(f > 3.0 && f < 10.0, "factor {f}");
    }

    #[test]
    fn fig_twotier_beats_flat_for_k_ge_2_and_inter_bytes_are_one_kth() {
        let rows = fig_twotier(None).unwrap();
        // Full matrix: 3 strategies x every registered codec x 4 k values.
        assert_eq!(rows.len(), 3 * crate::compress::Codec::all().len() * 4);
        for r in &rows {
            let tag = format!("{}/{} k={}", r.strategy, r.codec, r.devices);
            // The acceptance gate: exact integer 1/k on the NIC.
            assert_eq!(
                r.two_tier_inter_bytes * r.devices as u64,
                r.flat_inter_bytes,
                "{tag}"
            );
            assert_eq!(r.flat_intra_bytes, 0, "{tag}");
            if r.devices >= 2 {
                // The payoff claim: strictly faster at every matrix cell.
                assert!(
                    r.two_tier_epoch_s < r.flat_epoch_s,
                    "{tag}: two-tier {} !< flat {}",
                    r.two_tier_epoch_s,
                    r.flat_epoch_s
                );
                assert!(r.two_tier_intra_bytes > 0, "{tag}");
            } else {
                // k = 1: no device tier to exploit — two-tier must never
                // *appear* to win (satellite 4's no-false-win rule).
                assert!(r.two_tier_epoch_s >= r.flat_epoch_s - 1e-12, "{tag}");
                assert_eq!(r.two_tier_inter_bytes, r.flat_inter_bytes, "{tag}");
                assert_eq!(r.two_tier_intra_bytes, 0, "{tag}");
            }
        }
    }

    #[test]
    fn intranode_numbers_match_paper() {
        let t = intranode_table();
        let get = |name: &str| t.iter().find(|(n, _)| *n == name).unwrap().1;
        assert!((get("IBMGpu reduce -> host") - 30.0).abs() < 0.1);
        assert!((get("NCCL reduce (1 comm set)") - 12.0).abs() < 0.1);
        assert!((get("broadcast host -> GPUs") - 28.0).abs() < 0.1);
        assert!((get("host write BW bound") - 38.4).abs() < 0.1);
    }
}
