//! Thread-safe front-end to the model runtime.
//!
//! One dedicated thread owns the loaded model and serves requests over a
//! channel; every worker thread holds a cloneable [`ModelHandle`]. Besides
//! matching the original PJRT constraint (`PjRtClient` is not `Send`), the
//! service thread faithfully models the paper's setup, where all DL
//! workers of a node share its GPUs through a device queue.

use super::{Model, ModelMeta, Runtime, XData};
use crate::optimizer::SgdHyper;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

enum Req {
    Grad {
        params: Vec<f32>,
        x: XData,
        y: Vec<i32>,
        /// `None` = compiled batch; `Some(r)` = short per-device shard of
        /// r rows (the device tier splits b into k shards of b/k).
        rows: Option<usize>,
        reply: Sender<Result<(f32, Vec<f32>)>>,
    },
    Eval {
        params: Vec<f32>,
        x: XData,
        y: Vec<i32>,
        reply: Sender<Result<(f32, i32)>>,
    },
    Sgd {
        w: Vec<f32>,
        g: Vec<f32>,
        m: Vec<f32>,
        hyper: SgdHyper,
        reply: Sender<Result<(Vec<f32>, Vec<f32>)>>,
    },
    Elastic1 {
        center: Vec<f32>,
        w: Vec<f32>,
        alpha: f32,
        reply: Sender<Result<Vec<f32>>>,
    },
    Elastic2 {
        w: Vec<f32>,
        center: Vec<f32>,
        alpha: f32,
        reply: Sender<Result<Vec<f32>>>,
    },
    Shutdown,
}

/// Owns the PJRT thread; dropped last.
pub struct ModelService {
    tx: Sender<Req>,
    pub meta: ModelMeta,
    thread: Option<JoinHandle<()>>,
}

/// Cloneable handle used by worker threads.
#[derive(Clone)]
pub struct ModelHandle {
    tx: Sender<Req>,
    pub meta: ModelMeta,
}

impl ModelService {
    /// Spawn the service thread, loading `variant` from `artifacts_dir`.
    pub fn spawn(artifacts_dir: PathBuf, variant: &str) -> Result<Self> {
        let (tx, rx) = channel::<Req>();
        let (meta_tx, meta_rx) = channel::<Result<ModelMeta>>();
        let variant = variant.to_string();
        let thread = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                let model = (|| -> Result<Model> {
                    let rt = Runtime::cpu()?;
                    Model::load(&rt, &artifacts_dir, &variant)
                })();
                let model = match model {
                    Ok(m) => {
                        let _ = meta_tx.send(Ok(m.meta.clone()));
                        m
                    }
                    Err(e) => {
                        let _ = meta_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Grad { params, x, y, rows, reply } => {
                            let r = match rows {
                                None => model.grad_step(&params, &x, &y),
                                Some(rows) => model.grad_step_rows(&params, &x, &y, rows),
                            };
                            let _ = reply.send(r);
                        }
                        Req::Eval { params, x, y, reply } => {
                            let _ = reply.send(model.eval_step(&params, &x, &y));
                        }
                        Req::Sgd { mut w, g, mut m, hyper, reply } => {
                            let r = model
                                .sgd_update(&mut w, &g, &mut m, &hyper)
                                .map(|()| (w, m));
                            let _ = reply.send(r);
                        }
                        Req::Elastic1 { mut center, w, alpha, reply } => {
                            let r = model.elastic1(&mut center, &w, alpha).map(|()| center);
                            let _ = reply.send(r);
                        }
                        Req::Elastic2 { mut w, center, alpha, reply } => {
                            let r = model.elastic2(&mut w, &center, alpha).map(|()| w);
                            let _ = reply.send(r);
                        }
                        Req::Shutdown => break,
                    }
                }
            })?;
        let meta = meta_rx
            .recv()
            .context("pjrt service thread died during load")??;
        Ok(Self { tx, meta, thread: Some(thread) })
    }

    pub fn handle(&self) -> ModelHandle {
        ModelHandle { tx: self.tx.clone(), meta: self.meta.clone() }
    }
}

impl Drop for ModelService {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl ModelHandle {
    pub fn grad_step(&self, params: &[f32], x: XData, y: Vec<i32>) -> Result<(f32, Vec<f32>)> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Grad { params: params.to_vec(), x, y, rows: None, reply })
            .context("pjrt service gone")?;
        rx.recv().context("pjrt service dropped request")?
    }

    /// Short-batch gradient over `rows` rows — one device's shard of the
    /// worker batch when the device tier is on (`devices > 1`).
    pub fn grad_step_rows(
        &self,
        params: &[f32],
        x: XData,
        y: Vec<i32>,
        rows: usize,
    ) -> Result<(f32, Vec<f32>)> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Grad { params: params.to_vec(), x, y, rows: Some(rows), reply })
            .context("pjrt service gone")?;
        rx.recv().context("pjrt service dropped request")?
    }

    pub fn eval_step(&self, params: &[f32], x: XData, y: Vec<i32>) -> Result<(f32, i32)> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Eval { params: params.to_vec(), x, y, reply })
            .context("pjrt service gone")?;
        rx.recv().context("pjrt service dropped request")?
    }

    /// `(w, m) <- fused_sgd(hyper, w, g, m)` on the service thread.
    pub fn sgd_update(
        &self,
        w: &mut Vec<f32>,
        g: &[f32],
        m: &mut Vec<f32>,
        hyper: &SgdHyper,
    ) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Sgd {
                w: std::mem::take(w),
                g: g.to_vec(),
                m: std::mem::take(m),
                hyper: *hyper,
                reply,
            })
            .context("pjrt service gone")?;
        let (nw, nm) = rx.recv().context("pjrt service dropped request")??;
        *w = nw;
        *m = nm;
        Ok(())
    }

    pub fn elastic1(&self, center: &mut Vec<f32>, w: &[f32], alpha: f32) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Elastic1 {
                center: std::mem::take(center),
                w: w.to_vec(),
                alpha,
                reply,
            })
            .context("pjrt service gone")?;
        *center = rx.recv().context("pjrt service dropped request")??;
        Ok(())
    }

    pub fn elastic2(&self, w: &mut Vec<f32>, center: &[f32], alpha: f32) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Elastic2 {
                w: std::mem::take(w),
                center: center.to_vec(),
                alpha,
                reply,
            })
            .context("pjrt service gone")?;
        *w = rx.recv().context("pjrt service dropped request")??;
        Ok(())
    }
}
