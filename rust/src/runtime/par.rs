//! Deterministic thread-parallelism for the native compute plane.
//!
//! Every kernel in `runtime::native` partitions its *output* across
//! threads in fixed-size contiguous row blocks and keeps the summation
//! order of each output element a pure function of the problem size.
//! Consequence: results are bitwise identical at any thread count, so
//! the cross-plane equivalence properties (threaded trainer vs netsim,
//! MPI vs single-process) hold regardless of the `threads` knob, and the
//! knob is a pure performance control.
//!
//! The building blocks here are:
//!
//! - a process-global thread-count knob ([`set_threads`] / [`threads`]),
//!   0 = auto (all available parallelism), 1 = scalar path;
//! - a work threshold ([`set_min_work`]) below which kernels stay on the
//!   calling thread — spawning costs tens of microseconds, so test-sized
//!   problems must not fan out (property tests lower the threshold to
//!   force the parallel path at tiny shapes);
//! - [`par_rows`] / [`par_rows2`] / [`par_rows3`]: run a row-range
//!   closure over co-partitioned output slices via `std::thread::scope`
//!   (no dependencies; rayon is not in the image);
//! - fixed-lane reduction helpers ([`dot_lanes`], [`sum_lanes`],
//!   [`reduce_lanes`]) whose accumulation order depends only on the
//!   input length, never on threading — the autovectorizable replacement
//!   for a single sequential `f32` accumulator.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Default work threshold (inner-loop op count) below which kernels run
/// on the calling thread. ~2M f32 ops is a few hundred microseconds of
/// scalar work — an order of magnitude above thread-spawn cost.
pub const DEFAULT_MIN_WORK: usize = 1 << 21;

static THREADS: AtomicUsize = AtomicUsize::new(0);
static MIN_WORK: AtomicUsize = AtomicUsize::new(DEFAULT_MIN_WORK);

/// Set the compute-plane thread count. 0 = auto (available parallelism),
/// 1 = force the scalar path. Results are bitwise independent of this
/// knob, so flipping it mid-run is harmless.
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// Effective thread count after resolving 0 = auto.
pub fn threads() -> usize {
    match THREADS.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism().map_or(1, |n| n.get()),
        n => n,
    }
}

/// Test hook: override the parallelism work threshold so property tests
/// can drive the multi-threaded path at test-sized shapes. Restore with
/// [`DEFAULT_MIN_WORK`].
pub fn set_min_work(n: usize) {
    MIN_WORK.store(n, Ordering::Relaxed);
}

fn min_work() -> usize {
    MIN_WORK.load(Ordering::Relaxed)
}

/// Run `f` over `rows` rows of three co-partitioned output slices.
///
/// Each slice is split into the same contiguous row ranges (widths
/// derived as `len / rows`; empty slices are allowed) and `f(row0,
/// chunk_a, chunk_b, chunk_c)` runs once per range. Below the work
/// threshold — or with one thread — this is a single `f(0, a, b, c)`
/// call, so `f` must be insensitive to how rows are grouped into calls
/// (all our kernels are: per-row work is independent, and any in-call
/// tiling is itself per-row).
pub fn par_rows3<A, B, C, F>(a: &mut [A], b: &mut [B], c: &mut [C], rows: usize, work: usize, f: F)
where
    A: Send,
    B: Send,
    C: Send,
    F: Fn(usize, &mut [A], &mut [B], &mut [C]) + Sync,
{
    if rows == 0 {
        return;
    }
    debug_assert_eq!(a.len() % rows, 0);
    debug_assert_eq!(b.len() % rows, 0);
    debug_assert_eq!(c.len() % rows, 0);
    let (wa, wb, wc) = (a.len() / rows, b.len() / rows, c.len() / rows);
    let t = threads().min(rows);
    if t <= 1 || work < min_work() {
        f(0, a, b, c);
        return;
    }
    let per = rows.div_ceil(t);
    std::thread::scope(|scope| {
        let f = &f;
        let (mut ra, mut rb, mut rc) = (a, b, c);
        let mut row = 0;
        while row < rows {
            let take = per.min(rows - row);
            let (ha, ta) = std::mem::take(&mut ra).split_at_mut(take * wa);
            let (hb, tb) = std::mem::take(&mut rb).split_at_mut(take * wb);
            let (hc, tc) = std::mem::take(&mut rc).split_at_mut(take * wc);
            (ra, rb, rc) = (ta, tb, tc);
            scope.spawn(move || f(row, ha, hb, hc));
            row += take;
        }
    });
}

/// Two-slice variant of [`par_rows3`].
pub fn par_rows2<A, B, F>(a: &mut [A], b: &mut [B], rows: usize, work: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    par_rows3::<A, B, (), _>(a, b, &mut [], rows, work, |r, ca, cb, _| f(r, ca, cb));
}

/// Single-slice variant of [`par_rows3`].
pub fn par_rows<A, F>(a: &mut [A], rows: usize, work: usize, f: F)
where
    A: Send,
    F: Fn(usize, &mut [A]) + Sync,
{
    par_rows3::<A, (), (), _>(a, &mut [], &mut [], rows, work, |r, ca, _, _| f(r, ca));
}

/// Lane count for the fixed-order chunked accumulators. Matches one
/// 256-bit vector of f32 — wide enough for the compiler to vectorize,
/// fixed so the reduction order never depends on threading.
pub const LANES: usize = 8;

/// Fold the lane accumulators in a fixed pairwise tree. The order is a
/// constant of this function — part of the determinism contract.
pub fn reduce_lanes(acc: &[f32; LANES]) -> f32 {
    let even = (acc[0] + acc[4]) + (acc[2] + acc[6]);
    let odd = (acc[1] + acc[5]) + (acc[3] + acc[7]);
    even + odd
}

/// Dot product with [`LANES`] parallel accumulators: chunk `i` of 8
/// elements adds into lanes 0..8, the remainder accumulates
/// sequentially, and [`reduce_lanes`] folds the lanes. The summation
/// order depends only on the slice length.
pub fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let mut ia = a.chunks_exact(LANES);
    let mut ib = b.chunks_exact(LANES);
    for (ca, cb) in (&mut ia).zip(&mut ib) {
        for ((s, &x), &y) in acc.iter_mut().zip(ca).zip(cb) {
            *s += x * y;
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in ia.remainder().iter().zip(ib.remainder()) {
        tail += x * y;
    }
    reduce_lanes(&acc) + tail
}

/// Sum with [`LANES`] parallel accumulators; same order contract as
/// [`dot_lanes`].
pub fn sum_lanes(a: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut it = a.chunks_exact(LANES);
    for ca in &mut it {
        for (s, &x) in acc.iter_mut().zip(ca) {
            *s += x;
        }
    }
    let mut tail = 0.0f32;
    for &x in it.remainder() {
        tail += x;
    }
    reduce_lanes(&acc) + tail
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_rows_covers_all_rows_once() {
        // 7 rows, width 3: every element written exactly once whatever
        // the partitioning.
        let mut out = vec![0.0f32; 21];
        set_min_work(0);
        par_rows(&mut out, 7, usize::MAX, |r0, chunk| {
            for (i, row) in chunk.chunks_exact_mut(3).enumerate() {
                for (j, v) in row.iter_mut().enumerate() {
                    *v += ((r0 + i) * 3 + j) as f32 + 1.0;
                }
            }
        });
        set_min_work(DEFAULT_MIN_WORK);
        let want: Vec<f32> = (1..=21).map(|v| v as f32).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn lane_helpers_match_exact_integer_sums() {
        // Integer-valued f32s are exact under any summation order.
        let a: Vec<f32> = (1..=19).map(|v| v as f32).collect();
        let b = vec![2.0f32; 19];
        assert_eq!(sum_lanes(&a), 190.0);
        assert_eq!(dot_lanes(&a, &b), 380.0);
    }

    #[test]
    fn threads_auto_resolves_nonzero() {
        set_threads(0);
        assert!(threads() >= 1);
        set_threads(3);
        assert_eq!(threads(), 3);
        set_threads(0);
    }
}
