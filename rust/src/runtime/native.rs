//! Native CPU kernels for the model variants: the offline substitute for
//! the PJRT/HLO execution path.
//!
//! The build image has no `xla` crate and no network to fetch one, so the
//! L2 models of `python/compile/model.py` are mirrored here natively:
//! identical architectures, identical loss (mean softmax cross-entropy via
//! logsumexp), identical LayerNorm/GELU conventions (eps 1e-5, tanh
//! approximation — `jax.nn.gelu(approximate=True)`). The forward/backward
//! math in this file was validated against `jax.value_and_grad` on the
//! Python definitions (max relative gradient error ~3e-5 at f32); the
//! in-tree finite-difference tests below guard the port.
//!
//! Parameters stay one flat `f32` vector addressed through the
//! [`SegmentTable`] from `meta.json`, exactly like the AOT calling
//! convention, so KVStore keys / trainers are unaffected by the backend.

use crate::tensor::SegmentTable;

const LN_EPS: f32 = 1e-5;

// ---------------------------------------------------------------------------
// Flat-buffer math helpers
// ---------------------------------------------------------------------------

/// y[m,n] = x[m,k] @ w[k,n]
fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    let mut y = vec![0.0f32; m * n];
    for i in 0..m {
        let yrow = &mut y[i * n..(i + 1) * n];
        for l in 0..k {
            let a = x[i * k + l];
            if a != 0.0 {
                let wrow = &w[l * n..(l + 1) * n];
                for j in 0..n {
                    yrow[j] += a * wrow[j];
                }
            }
        }
    }
    y
}

/// g[k,n] = x^T[k,m] @ dy[m,n] (weight gradient).
fn matmul_tn(x: &[f32], dy: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dy.len(), m * n);
    let mut g = vec![0.0f32; k * n];
    for i in 0..m {
        let dyrow = &dy[i * n..(i + 1) * n];
        for l in 0..k {
            let a = x[i * k + l];
            if a != 0.0 {
                let grow = &mut g[l * n..(l + 1) * n];
                for j in 0..n {
                    grow[j] += a * dyrow[j];
                }
            }
        }
    }
    g
}

/// dx[m,k] = dy[m,n] @ w^T[n,k] (input gradient).
fn matmul_nt(dy: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    let mut dx = vec![0.0f32; m * k];
    for i in 0..m {
        let dyrow = &dy[i * n..(i + 1) * n];
        for l in 0..k {
            let wrow = &w[l * n..(l + 1) * n];
            let mut s = 0.0f32;
            for j in 0..n {
                s += dyrow[j] * wrow[j];
            }
            dx[i * k + l] = s;
        }
    }
    dx
}

fn add_bias(y: &mut [f32], bias: &[f32], m: usize, n: usize) {
    for i in 0..m {
        let row = &mut y[i * n..(i + 1) * n];
        for j in 0..n {
            row[j] += bias[j];
        }
    }
}

/// Column sums of dy[m,n] (bias gradient).
fn col_sum(dy: &[f32], m: usize, n: usize) -> Vec<f32> {
    let mut s = vec![0.0f32; n];
    for i in 0..m {
        let row = &dy[i * n..(i + 1) * n];
        for j in 0..n {
            s[j] += row[j];
        }
    }
    s
}

/// Mean softmax cross-entropy over `rows` rows of `v` logits.
/// Returns (mean loss, dlogits = (softmax - onehot)/rows, n_correct).
fn softmax_xent(logits: &[f32], y: &[i32], rows: usize, v: usize) -> (f32, Vec<f32>, i32) {
    debug_assert_eq!(logits.len(), rows * v);
    debug_assert_eq!(y.len(), rows);
    let mut dl = vec![0.0f32; rows * v];
    let mut loss = 0.0f64;
    let mut correct = 0i32;
    for i in 0..rows {
        let row = &logits[i * v..(i + 1) * v];
        let gold = y[i] as usize;
        debug_assert!(gold < v, "label out of range");
        let mut mx = f32::NEG_INFINITY;
        let mut arg = 0usize;
        for (j, &x) in row.iter().enumerate() {
            if x > mx {
                mx = x;
                arg = j;
            }
        }
        if arg == gold {
            correct += 1;
        }
        let mut z = 0.0f32;
        for &x in row {
            z += (x - mx).exp();
        }
        loss += (z.ln() + mx - row[gold]) as f64;
        let drow = &mut dl[i * v..(i + 1) * v];
        for j in 0..v {
            drow[j] = (row[j] - mx).exp() / z;
        }
        drow[gold] -= 1.0;
    }
    let inv = 1.0 / rows as f32;
    for d in dl.iter_mut() {
        *d *= inv;
    }
    ((loss / rows as f64) as f32, dl, correct)
}

/// LayerNorm forward over `rows` rows of width `d`.
/// Returns (y, xhat, rstd) — the backward caches.
fn ln_fwd(
    x: &[f32],
    scale: &[f32],
    bias: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; rows * d];
    let mut xhat = vec![0.0f32; rows * d];
    let mut rstd = vec![0.0f32; rows];
    let dn = d as f32;
    for i in 0..rows {
        let row = &x[i * d..(i + 1) * d];
        let mut mu = 0.0f32;
        for &v in row {
            mu += v;
        }
        mu /= dn;
        let mut var = 0.0f32;
        for &v in row {
            var += (v - mu) * (v - mu);
        }
        var /= dn;
        let r = 1.0 / (var + LN_EPS).sqrt();
        rstd[i] = r;
        for j in 0..d {
            let xh = (row[j] - mu) * r;
            xhat[i * d + j] = xh;
            y[i * d + j] = xh * scale[j] + bias[j];
        }
    }
    (y, xhat, rstd)
}

/// LayerNorm backward. Returns (dx, dscale, dbias).
fn ln_bwd(
    dy: &[f32],
    scale: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; rows * d];
    let mut dscale = vec![0.0f32; d];
    let mut dbias = vec![0.0f32; d];
    let dn = d as f32;
    for i in 0..rows {
        let mut mg = 0.0f32;
        let mut mgx = 0.0f32;
        for j in 0..d {
            let dyv = dy[i * d + j];
            let xh = xhat[i * d + j];
            let gg = dyv * scale[j];
            mg += gg;
            mgx += gg * xh;
            dscale[j] += dyv * xh;
            dbias[j] += dyv;
        }
        mg /= dn;
        mgx /= dn;
        for j in 0..d {
            let gg = dy[i * d + j] * scale[j];
            dx[i * d + j] = (gg - mg - xhat[i * d + j] * mgx) * rstd[i];
        }
    }
    (dx, dscale, dbias)
}

/// GELU (tanh approximation) forward; returns (y, tanh cache).
fn gelu_fwd(x: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let c0 = (2.0f32 / std::f32::consts::PI).sqrt();
    let mut y = vec![0.0f32; x.len()];
    let mut t = vec![0.0f32; x.len()];
    for i in 0..x.len() {
        let v = x[i];
        let u = c0 * (v + 0.044715 * v * v * v);
        let th = u.tanh();
        t[i] = th;
        y[i] = 0.5 * v * (1.0 + th);
    }
    (y, t)
}

/// GELU backward: dy -> dx, given the input x and the tanh cache.
fn gelu_bwd(dy: &[f32], x: &[f32], t: &[f32]) -> Vec<f32> {
    let c0 = (2.0f32 / std::f32::consts::PI).sqrt();
    let mut dx = vec![0.0f32; x.len()];
    for i in 0..x.len() {
        let v = x[i];
        let th = t[i];
        let du = c0 * (1.0 + 3.0 * 0.044715 * v * v);
        dx[i] = dy[i] * (0.5 * (1.0 + th) + 0.5 * v * (1.0 - th * th) * du);
    }
    dx
}

/// Parameter slice by segment name.
fn p<'a>(w: &'a [f32], segs: &SegmentTable, name: &str) -> &'a [f32] {
    let s = segs
        .by_name(name)
        .unwrap_or_else(|| panic!("missing parameter segment {name:?}"));
    &w[s.offset..s.offset + s.size]
}

/// Accumulate a gradient slice by segment name.
fn add_grad(g: &mut [f32], segs: &SegmentTable, name: &str, src: &[f32]) {
    let s = segs
        .by_name(name)
        .unwrap_or_else(|| panic!("missing parameter segment {name:?}"));
    assert_eq!(s.size, src.len(), "gradient size mismatch for {name:?}");
    let dst = &mut g[s.offset..s.offset + s.size];
    for (d, v) in dst.iter_mut().zip(src) {
        *d += v;
    }
}

// ---------------------------------------------------------------------------
// Residual MLP (the "ResNet" stand-in)
// ---------------------------------------------------------------------------

/// Mirror of `MlpConfig` + `mlp_logits` in python/compile/model.py.
#[derive(Debug, Clone)]
pub struct MlpModel {
    pub batch: usize,
    pub input_dim: usize,
    pub hidden: usize,
    pub blocks: usize,
    pub classes: usize,
}

struct MlpForward {
    /// hs[0] = relu of the input layer; hs[i+1] = block i output.
    hs: Vec<Vec<f32>>,
    /// Per-block relu(z1) activations.
    z1s: Vec<Vec<f32>>,
    logits: Vec<f32>,
}

impl MlpModel {
    fn forward(&self, segs: &SegmentTable, w: &[f32], x: &[f32]) -> MlpForward {
        let (b, d, h, c) = (self.batch, self.input_dim, self.hidden, self.classes);
        let mut h0 = matmul(x, p(w, segs, "in.w"), b, d, h);
        add_bias(&mut h0, p(w, segs, "in.b"), b, h);
        for v in h0.iter_mut() {
            *v = v.max(0.0);
        }
        let mut hs = vec![h0];
        let mut z1s = Vec::with_capacity(self.blocks);
        for i in 0..self.blocks {
            let (z1, hout) = {
                let hin = &hs[i];
                let mut a1 = matmul(hin, p(w, segs, &format!("block{i}.w1")), b, h, h);
                add_bias(&mut a1, p(w, segs, &format!("block{i}.b1")), b, h);
                for v in a1.iter_mut() {
                    *v = v.max(0.0);
                }
                let mut a2 = matmul(&a1, p(w, segs, &format!("block{i}.w2")), b, h, h);
                add_bias(&mut a2, p(w, segs, &format!("block{i}.b2")), b, h);
                for (j, v) in a2.iter_mut().enumerate() {
                    *v = (hin[j] + *v).max(0.0);
                }
                (a1, a2)
            };
            z1s.push(z1);
            hs.push(hout);
        }
        let mut logits = matmul(&hs[self.blocks], p(w, segs, "head.w"), b, h, c);
        add_bias(&mut logits, p(w, segs, "head.b"), b, c);
        MlpForward { hs, z1s, logits }
    }

    pub fn grad_step(
        &self,
        segs: &SegmentTable,
        w: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> (f32, Vec<f32>) {
        let (b, d, h, c) = (self.batch, self.input_dim, self.hidden, self.classes);
        let fwd = self.forward(segs, w, x);
        let (loss, dl, _) = softmax_xent(&fwd.logits, y, b, c);

        let mut g = vec![0.0f32; segs.total_size()];
        add_grad(&mut g, segs, "head.w", &matmul_tn(&fwd.hs[self.blocks], &dl, b, h, c));
        add_grad(&mut g, segs, "head.b", &col_sum(&dl, b, c));
        let mut dh = matmul_nt(&dl, p(w, segs, "head.w"), b, c, h);
        for i in (0..self.blocks).rev() {
            let hin = &fwd.hs[i];
            let hout = &fwd.hs[i + 1];
            let z1 = &fwd.z1s[i];
            // h_out = relu(h_in + a2): mask the residual-sum gradient.
            let mut dsum = dh.clone();
            for j in 0..b * h {
                if hout[j] <= 0.0 {
                    dsum[j] = 0.0;
                }
            }
            let w2 = p(w, segs, &format!("block{i}.w2"));
            add_grad(&mut g, segs, &format!("block{i}.w2"), &matmul_tn(z1, &dsum, b, h, h));
            add_grad(&mut g, segs, &format!("block{i}.b2"), &col_sum(&dsum, b, h));
            let mut da1 = matmul_nt(&dsum, w2, b, h, h);
            for j in 0..b * h {
                if z1[j] <= 0.0 {
                    da1[j] = 0.0;
                }
            }
            let w1 = p(w, segs, &format!("block{i}.w1"));
            add_grad(&mut g, segs, &format!("block{i}.w1"), &matmul_tn(hin, &da1, b, h, h));
            add_grad(&mut g, segs, &format!("block{i}.b1"), &col_sum(&da1, b, h));
            let dh_prev = matmul_nt(&da1, w1, b, h, h);
            for j in 0..b * h {
                dh[j] = dsum[j] + dh_prev[j];
            }
        }
        let h0 = &fwd.hs[0];
        let mut da = dh;
        for j in 0..b * h {
            if h0[j] <= 0.0 {
                da[j] = 0.0;
            }
        }
        add_grad(&mut g, segs, "in.w", &matmul_tn(x, &da, b, d, h));
        add_grad(&mut g, segs, "in.b", &col_sum(&da, b, h));
        (loss, g)
    }

    pub fn eval_step(&self, segs: &SegmentTable, w: &[f32], x: &[f32], y: &[i32]) -> (f32, i32) {
        let fwd = self.forward(segs, w, x);
        let (loss, _, correct) = softmax_xent(&fwd.logits, y, self.batch, self.classes);
        (loss, correct)
    }
}

// ---------------------------------------------------------------------------
// Decoder-only transformer LM (tied embedding head)
// ---------------------------------------------------------------------------

/// Mirror of `TransformerConfig` + `transformer_logits` in model.py.
#[derive(Debug, Clone)]
pub struct TransformerModel {
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
}

struct LayerCache {
    ln1: Vec<f32>,
    xhat1: Vec<f32>,
    rstd1: Vec<f32>,
    qkv: Vec<f32>,
    /// [b, heads, s, s] attention probabilities (0 above the diagonal).
    prob: Vec<f32>,
    o: Vec<f32>,
    ln2: Vec<f32>,
    xhat2: Vec<f32>,
    rstd2: Vec<f32>,
    a_ff: Vec<f32>,
    tanh: Vec<f32>,
    gl: Vec<f32>,
}

struct TfForward {
    layers: Vec<LayerCache>,
    xf: Vec<f32>,
    xhat_f: Vec<f32>,
    rstd_f: Vec<f32>,
    logits: Vec<f32>,
}

impl TransformerModel {
    fn forward(&self, segs: &SegmentTable, w: &[f32], tokens: &[i32]) -> TfForward {
        let (b, s, d, hn, f, v) = (
            self.batch,
            self.seq,
            self.d_model,
            self.n_heads,
            self.d_ff,
            self.vocab,
        );
        let hd = d / hn;
        let inv = 1.0 / (hd as f32).sqrt();
        let bs = b * s;
        let embed = p(w, segs, "embed");
        let pos = p(w, segs, "pos");

        let mut x = vec![0.0f32; bs * d];
        for i in 0..bs {
            let t = tokens[i] as usize;
            debug_assert!(t < v, "token out of range");
            let si = i % s;
            for dd in 0..d {
                x[i * d + dd] = embed[t * d + dd] + pos[si * d + dd];
            }
        }

        let mut layers = Vec::with_capacity(self.n_layers);
        for li in 0..self.n_layers {
            let (ln1, xhat1, rstd1) = ln_fwd(
                &x,
                p(w, segs, &format!("layer{li}.ln1.scale")),
                p(w, segs, &format!("layer{li}.ln1.bias")),
                bs,
                d,
            );
            let qkv = matmul(&ln1, p(w, segs, &format!("layer{li}.qkv")), bs, d, 3 * d);
            let mut prob = vec![0.0f32; b * hn * s * s];
            let mut o = vec![0.0f32; bs * d];
            for bb in 0..b {
                for h in 0..hn {
                    for qi in 0..s {
                        let qoff = (bb * s + qi) * 3 * d + h * hd;
                        let mut row = vec![0.0f32; qi + 1];
                        let mut mx = f32::NEG_INFINITY;
                        for (ki, rv) in row.iter_mut().enumerate() {
                            let koff = (bb * s + ki) * 3 * d + d + h * hd;
                            let mut dot = 0.0f32;
                            for e in 0..hd {
                                dot += qkv[qoff + e] * qkv[koff + e];
                            }
                            *rv = dot * inv;
                            mx = mx.max(*rv);
                        }
                        let mut z = 0.0f32;
                        for rv in row.iter_mut() {
                            *rv = (*rv - mx).exp();
                            z += *rv;
                        }
                        let pr = &mut prob[((bb * hn + h) * s + qi) * s..][..s];
                        for (ki, rv) in row.iter().enumerate() {
                            pr[ki] = rv / z;
                        }
                        let ooff = (bb * s + qi) * d + h * hd;
                        for e in 0..hd {
                            let mut acc = 0.0f32;
                            for (ki, pv) in pr[..=qi].iter().enumerate() {
                                acc += pv * qkv[(bb * s + ki) * 3 * d + 2 * d + h * hd + e];
                            }
                            o[ooff + e] = acc;
                        }
                    }
                }
            }
            let attn = matmul(&o, p(w, segs, &format!("layer{li}.attn_out")), bs, d, d);
            let mut x1 = x;
            for j in 0..bs * d {
                x1[j] += attn[j];
            }
            let (ln2, xhat2, rstd2) = ln_fwd(
                &x1,
                p(w, segs, &format!("layer{li}.ln2.scale")),
                p(w, segs, &format!("layer{li}.ln2.bias")),
                bs,
                d,
            );
            let mut a_ff = matmul(&ln2, p(w, segs, &format!("layer{li}.ff1")), bs, d, f);
            add_bias(&mut a_ff, p(w, segs, &format!("layer{li}.ff1_b")), bs, f);
            let (gl, tanh) = gelu_fwd(&a_ff);
            let ff_out = matmul(&gl, p(w, segs, &format!("layer{li}.ff2")), bs, f, d);
            let ff2_b = p(w, segs, &format!("layer{li}.ff2_b"));
            let mut x2 = x1;
            for i in 0..bs {
                for dd in 0..d {
                    x2[i * d + dd] += ff_out[i * d + dd] + ff2_b[dd];
                }
            }
            layers.push(LayerCache {
                ln1,
                xhat1,
                rstd1,
                qkv,
                prob,
                o,
                ln2,
                xhat2,
                rstd2,
                a_ff,
                tanh,
                gl,
            });
            x = x2;
        }
        let (xf, xhat_f, rstd_f) =
            ln_fwd(&x, p(w, segs, "lnf.scale"), p(w, segs, "lnf.bias"), bs, d);
        // Tied head: logits = xf @ embed^T.
        let mut logits = vec![0.0f32; bs * v];
        for i in 0..bs {
            let xrow = &xf[i * d..(i + 1) * d];
            let lrow = &mut logits[i * v..(i + 1) * v];
            for (t, lv) in lrow.iter_mut().enumerate() {
                let erow = &embed[t * d..(t + 1) * d];
                let mut dot = 0.0f32;
                for dd in 0..d {
                    dot += xrow[dd] * erow[dd];
                }
                *lv = dot;
            }
        }
        TfForward { layers, xf, xhat_f, rstd_f, logits }
    }

    pub fn grad_step(
        &self,
        segs: &SegmentTable,
        w: &[f32],
        tokens: &[i32],
        y: &[i32],
    ) -> (f32, Vec<f32>) {
        let (b, s, d, hn, f, v) = (
            self.batch,
            self.seq,
            self.d_model,
            self.n_heads,
            self.d_ff,
            self.vocab,
        );
        let hd = d / hn;
        let inv = 1.0 / (hd as f32).sqrt();
        let bs = b * s;
        let embed = p(w, segs, "embed");
        let fwd = self.forward(segs, w, tokens);
        let (loss, dl, _) = softmax_xent(&fwd.logits, y, bs, v);

        let mut g = vec![0.0f32; segs.total_size()];

        // Tied head: g_embed += dl^T @ xf; dxf = dl @ embed.
        let mut g_embed = vec![0.0f32; v * d];
        let mut dxf = vec![0.0f32; bs * d];
        for i in 0..bs {
            let dlrow = &dl[i * v..(i + 1) * v];
            let xrow = &fwd.xf[i * d..(i + 1) * d];
            let dxrow = &mut dxf[i * d..(i + 1) * d];
            for (t, &a) in dlrow.iter().enumerate() {
                if a != 0.0 {
                    let erow = &embed[t * d..(t + 1) * d];
                    let grow = &mut g_embed[t * d..(t + 1) * d];
                    for dd in 0..d {
                        grow[dd] += a * xrow[dd];
                        dxrow[dd] += a * erow[dd];
                    }
                }
            }
        }
        let (mut dx, dsc, dbi) = ln_bwd(
            &dxf,
            p(w, segs, "lnf.scale"),
            &fwd.xhat_f,
            &fwd.rstd_f,
            bs,
            d,
        );
        add_grad(&mut g, segs, "lnf.scale", &dsc);
        add_grad(&mut g, segs, "lnf.bias", &dbi);

        for li in (0..self.n_layers).rev() {
            let c = &fwd.layers[li];
            // x2 = x1 + gelu(ln2 @ ff1 + b1) @ ff2 + b2
            let ff2 = p(w, segs, &format!("layer{li}.ff2"));
            let dgl = matmul_nt(&dx, ff2, bs, d, f);
            add_grad(&mut g, segs, &format!("layer{li}.ff2"), &matmul_tn(&c.gl, &dx, bs, f, d));
            add_grad(&mut g, segs, &format!("layer{li}.ff2_b"), &col_sum(&dx, bs, d));
            let da = gelu_bwd(&dgl, &c.a_ff, &c.tanh);
            add_grad(&mut g, segs, &format!("layer{li}.ff1"), &matmul_tn(&c.ln2, &da, bs, d, f));
            add_grad(&mut g, segs, &format!("layer{li}.ff1_b"), &col_sum(&da, bs, f));
            let ff1 = p(w, segs, &format!("layer{li}.ff1"));
            let dln2 = matmul_nt(&da, ff1, bs, f, d);
            let (mut dx1, dsc, dbi) = ln_bwd(
                &dln2,
                p(w, segs, &format!("layer{li}.ln2.scale")),
                &c.xhat2,
                &c.rstd2,
                bs,
                d,
            );
            add_grad(&mut g, segs, &format!("layer{li}.ln2.scale"), &dsc);
            add_grad(&mut g, segs, &format!("layer{li}.ln2.bias"), &dbi);
            for j in 0..bs * d {
                dx1[j] += dx[j]; // residual around the FF block
            }
            // x1 = x0 + o @ attn_out
            let attn_out = p(w, segs, &format!("layer{li}.attn_out"));
            let do_ = matmul_nt(&dx1, attn_out, bs, d, d);
            add_grad(
                &mut g,
                segs,
                &format!("layer{li}.attn_out"),
                &matmul_tn(&c.o, &dx1, bs, d, d),
            );
            // Attention core: do_ -> dqkv.
            let mut dqkv = vec![0.0f32; bs * 3 * d];
            for bb in 0..b {
                for h in 0..hn {
                    for qi in 0..s {
                        let pr = &c.prob[((bb * hn + h) * s + qi) * s..][..s];
                        let dorow = &do_[(bb * s + qi) * d + h * hd..][..hd];
                        // dprob and sum(dprob * prob) over the causal range.
                        let mut dp = vec![0.0f32; qi + 1];
                        let mut sum_dp_p = 0.0f32;
                        for (ki, dpv) in dp.iter_mut().enumerate() {
                            let voff = (bb * s + ki) * 3 * d + 2 * d + h * hd;
                            let mut acc = 0.0f32;
                            for e in 0..hd {
                                acc += dorow[e] * c.qkv[voff + e];
                            }
                            *dpv = acc;
                            sum_dp_p += acc * pr[ki];
                        }
                        for ki in 0..=qi {
                            // dv[ki] += prob * do
                            let pv = pr[ki];
                            if pv != 0.0 {
                                let dvoff = (bb * s + ki) * 3 * d + 2 * d + h * hd;
                                for e in 0..hd {
                                    dqkv[dvoff + e] += pv * dorow[e];
                                }
                            }
                            // dscore (softmax backward), with the 1/sqrt(hd)
                            // factor folded in once for both dq and dk.
                            let ds = pv * (dp[ki] - sum_dp_p) * inv;
                            if ds != 0.0 {
                                let qoff = (bb * s + qi) * 3 * d + h * hd;
                                let koff = (bb * s + ki) * 3 * d + d + h * hd;
                                for e in 0..hd {
                                    dqkv[qoff + e] += ds * c.qkv[koff + e];
                                    dqkv[koff + e] += ds * c.qkv[qoff + e];
                                }
                            }
                        }
                    }
                }
            }
            add_grad(
                &mut g,
                segs,
                &format!("layer{li}.qkv"),
                &matmul_tn(&c.ln1, &dqkv, bs, d, 3 * d),
            );
            let wqkv = p(w, segs, &format!("layer{li}.qkv"));
            let dln1 = matmul_nt(&dqkv, wqkv, bs, 3 * d, d);
            let (dx0, dsc, dbi) = ln_bwd(
                &dln1,
                p(w, segs, &format!("layer{li}.ln1.scale")),
                &c.xhat1,
                &c.rstd1,
                bs,
                d,
            );
            add_grad(&mut g, segs, &format!("layer{li}.ln1.scale"), &dsc);
            add_grad(&mut g, segs, &format!("layer{li}.ln1.bias"), &dbi);
            for j in 0..bs * d {
                dx[j] = dx0[j] + dx1[j]; // residual around attention
            }
        }

        // x = embed[tokens] + pos
        let mut g_pos = vec![0.0f32; s * d];
        for i in 0..bs {
            let t = tokens[i] as usize;
            let si = i % s;
            for dd in 0..d {
                g_embed[t * d + dd] += dx[i * d + dd];
                g_pos[si * d + dd] += dx[i * d + dd];
            }
        }
        add_grad(&mut g, segs, "embed", &g_embed);
        add_grad(&mut g, segs, "pos", &g_pos);
        (loss, g)
    }

    pub fn eval_step(
        &self,
        segs: &SegmentTable,
        w: &[f32],
        tokens: &[i32],
        y: &[i32],
    ) -> (f32, i32) {
        let fwd = self.forward(segs, w, tokens);
        let (loss, _, correct) = softmax_xent(&fwd.logits, y, self.batch * self.seq, self.vocab);
        (loss, correct)
    }
}

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

/// A model variant executable natively on the CPU.
#[derive(Debug, Clone)]
pub enum NativeModel {
    Mlp(MlpModel),
    Transformer(TransformerModel),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Model, Runtime, XData};
    use crate::util::Rng;
    use std::path::PathBuf;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Central finite differences on the highest-|grad| coordinates: the
    /// backward pass must agree with the loss surface it claims to
    /// differentiate. Probing the largest entries keeps the f32 forward
    /// noise well below the measured delta.
    fn finite_diff_check(model: &Model, x: &XData, y: &[i32]) {
        let mut w = model.meta.init_params().unwrap();
        // Perturb away from the symmetric init so grads are generic.
        let mut rng = Rng::new(0xFD);
        for v in w.iter_mut() {
            *v += 0.02 * rng.normal() as f32;
        }
        let (_, grads) = model.grad_step(&w, x, y).unwrap();
        let mut idx: Vec<usize> = (0..grads.len()).collect();
        idx.sort_by(|&a, &b| grads[b].abs().total_cmp(&grads[a].abs()));
        let eps = 1e-2f32;
        for &i in idx.iter().take(16) {
            let orig = w[i];
            w[i] = orig + eps;
            let (lp, _) = model.grad_step(&w, x, y).unwrap();
            w[i] = orig - eps;
            let (lm, _) = model.grad_step(&w, x, y).unwrap();
            w[i] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let g = grads[i];
            assert!(
                (fd - g).abs() <= 0.05 * g.abs().max(0.05),
                "param {i}: fd {fd} vs grad {g}"
            );
        }
    }

    #[test]
    fn mlp_grad_matches_finite_difference() {
        let rt = Runtime::cpu().unwrap();
        let model = Model::load(&rt, &artifacts(), "mlp_tiny").unwrap();
        let batch = model.meta.batch_size();
        let dim = model.meta.x_shape[1] as usize;
        let data = crate::data::GaussianMixture::new(dim, 4, 0.5, 11);
        let b = data.batch(0, batch);
        finite_diff_check(&model, &XData::F32(b.x), &b.y);
    }

    #[test]
    fn transformer_grad_matches_finite_difference() {
        let rt = Runtime::cpu().unwrap();
        let model = Model::load(&rt, &artifacts(), "transformer_tiny").unwrap();
        let batch = model.meta.batch_size();
        let seq = model.meta.x_shape[1] as usize;
        let corpus = crate::data::TinyCorpus::new(64, 5);
        let (x, y) = corpus.batch_tokens(0, batch, seq);
        finite_diff_check(&model, &XData::I32(x), &y);
    }

    #[test]
    fn transformer_init_loss_near_uniform() {
        let rt = Runtime::cpu().unwrap();
        let model = Model::load(&rt, &artifacts(), "transformer_tiny").unwrap();
        let w = model.meta.init_params().unwrap();
        let corpus = crate::data::TinyCorpus::new(64, 5);
        let (x, y) = corpus.batch_tokens(0, model.meta.batch_size(), model.meta.x_shape[1] as usize);
        let (loss, _) = model.eval_step(&w, &XData::I32(x), &y).unwrap();
        assert!((loss - 64f32.ln()).abs() < 0.5, "init loss {loss}");
    }

    #[test]
    fn softmax_xent_uniform_and_onehot() {
        // Uniform logits: loss = ln(v), grad rows sum to 0.
        let (loss, dl, _) = softmax_xent(&[0.0; 8], &[3, 1], 2, 4);
        assert!((loss - 4.0f32.ln()).abs() < 1e-6);
        for i in 0..2 {
            let s: f32 = dl[i * 4..(i + 1) * 4].iter().sum();
            assert!(s.abs() < 1e-6);
        }
        // Confident correct logit: near-zero loss.
        let (loss, _, correct) = softmax_xent(&[20.0, 0.0, 0.0, 0.0], &[0], 1, 4);
        assert!(loss < 1e-3);
        assert_eq!(correct, 1);
    }

    #[test]
    fn layernorm_output_normalized() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let (y, _, _) = ln_fwd(&x, &[1.0; 4], &[0.0; 4], 1, 4);
        let mean: f32 = y.iter().sum::<f32>() / 4.0;
        let var: f32 = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn matmul_shapes_and_values() {
        // [2,3] @ [3,2]
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let y = matmul(&x, &w, 2, 3, 2);
        assert_eq!(y, vec![4.0, 5.0, 10.0, 11.0]);
        // Gradient identities: d(x@w)/dw with dy=1 equals column sums of x.
        let dy = vec![1.0; 4];
        let gw = matmul_tn(&x, &dy, 2, 3, 2);
        assert_eq!(gw, vec![5.0, 5.0, 7.0, 7.0, 9.0, 9.0]);
        let dx = matmul_nt(&dy, &w, 2, 2, 3);
        assert_eq!(dx, vec![1.0, 1.0, 2.0, 1.0, 1.0, 2.0]);
    }
}
