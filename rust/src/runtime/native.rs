//! Native CPU kernels for the model variants: the offline substitute for
//! the PJRT/HLO execution path.
//!
//! The build image has no `xla` crate and no network to fetch one, so the
//! L2 models of `python/compile/model.py` are mirrored here natively:
//! identical architectures, identical loss (mean softmax cross-entropy via
//! logsumexp), identical LayerNorm/GELU conventions (eps 1e-5, tanh
//! approximation — `jax.nn.gelu(approximate=True)`). The forward/backward
//! math in this file was validated against `jax.value_and_grad` on the
//! Python definitions (max relative gradient error ~3e-5 at f32); the
//! in-tree finite-difference tests below guard the port.
//!
//! Parameters stay one flat `f32` vector addressed through the
//! [`SegmentTable`] from `meta.json`, exactly like the AOT calling
//! convention, so KVStore keys / trainers are unaffected by the backend.

use crate::runtime::par;
use crate::tensor::SegmentTable;

const LN_EPS: f32 = 1e-5;

// ---------------------------------------------------------------------------
// Flat-buffer math helpers
//
// Every kernel here is parallelized with the `runtime::par` row
// partitioner under one determinism contract: the summation order of
// each output element is a pure function of the problem size — threads
// own disjoint contiguous output blocks and never split a reduction.
// Results are therefore bitwise identical at any `threads` setting,
// which is what keeps the cross-plane equivalence properties
// (tests/strategies.rs, tests/collective_algos.rs) independent of the
// performance knobs.
// ---------------------------------------------------------------------------

/// Cache tile depth: k-rows of `w` per tile in [`matmul`], m-rows of
/// `x` per tile in [`matmul_tn`]. 128 f32 rows at the widths used here
/// keep a tile L2-resident while a whole chunk of output rows sweeps it.
const MAT_KC: usize = 128;

/// y[m,n] = x[m,k] @ w[k,n]
///
/// Row-parallel and k-tiled; per output element the additions run in
/// ascending `l` exactly like the scalar reference (tiles are visited in
/// ascending order within each row), so the result is bitwise identical
/// to the single-threaded untiled kernel.
pub fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    let mut y = vec![0.0f32; m * n];
    if n == 0 {
        return y;
    }
    par::par_rows(&mut y, m, m * k * n, |r0, chunk| {
        for lb in (0..k).step_by(MAT_KC) {
            let le = (lb + MAT_KC).min(k);
            for (ii, yrow) in chunk.chunks_exact_mut(n).enumerate() {
                let xrow = &x[(r0 + ii) * k + lb..(r0 + ii) * k + le];
                for (dl, &a) in xrow.iter().enumerate() {
                    if a != 0.0 {
                        let wrow = &w[(lb + dl) * n..(lb + dl + 1) * n];
                        for (yv, &wv) in yrow.iter_mut().zip(wrow) {
                            *yv += a * wv;
                        }
                    }
                }
            }
        }
    });
    y
}

/// g[k,n] = x^T[k,m] @ dy[m,n] (weight gradient).
///
/// Parallel over the `k` output rows, tiled over `m`; per output element
/// the additions run in ascending `i` — the same order as the scalar
/// reference, so bitwise identical at any thread count.
pub fn matmul_tn(x: &[f32], dy: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(dy.len(), m * n);
    let mut g = vec![0.0f32; k * n];
    if n == 0 {
        return g;
    }
    par::par_rows(&mut g, k, m * k * n, |l0, chunk| {
        for ib in (0..m).step_by(MAT_KC) {
            let ie = (ib + MAT_KC).min(m);
            for (ll, grow) in chunk.chunks_exact_mut(n).enumerate() {
                let l = l0 + ll;
                for i in ib..ie {
                    let a = x[i * k + l];
                    if a != 0.0 {
                        let dyrow = &dy[i * n..(i + 1) * n];
                        for (gv, &dv) in grow.iter_mut().zip(dyrow) {
                            *gv += a * dv;
                        }
                    }
                }
            }
        }
    });
    g
}

/// dx[m,k] = dy[m,n] @ w^T[n,k] (input gradient).
///
/// Row-parallel dot products with the fixed-lane accumulators of
/// [`par::dot_lanes`]; the reduction order depends only on `n`, never on
/// threading.
pub fn matmul_nt(dy: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(dy.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    let mut dx = vec![0.0f32; m * k];
    if k == 0 {
        return dx;
    }
    par::par_rows(&mut dx, m, m * k * n, |r0, chunk| {
        for (ii, dxrow) in chunk.chunks_exact_mut(k).enumerate() {
            let dyrow = &dy[(r0 + ii) * n..(r0 + ii + 1) * n];
            for (l, dv) in dxrow.iter_mut().enumerate() {
                *dv = par::dot_lanes(dyrow, &w[l * n..(l + 1) * n]);
            }
        }
    });
    dx
}

pub fn add_bias(y: &mut [f32], bias: &[f32], m: usize, n: usize) {
    if n == 0 {
        return;
    }
    par::par_rows(y, m, m * n, |_, chunk| {
        for row in chunk.chunks_exact_mut(n) {
            for (v, &bv) in row.iter_mut().zip(bias) {
                *v += bv;
            }
        }
    });
}

/// Column sums of dy[m,n] (bias gradient). Parallel over *columns*;
/// each column still accumulates rows in ascending `i` — bitwise
/// identical to the scalar reference.
pub fn col_sum(dy: &[f32], m: usize, n: usize) -> Vec<f32> {
    let mut s = vec![0.0f32; n];
    par::par_rows(&mut s, n, m * n, |c0, chunk| {
        for i in 0..m {
            let row = &dy[i * n + c0..i * n + c0 + chunk.len()];
            for (sv, &v) in chunk.iter_mut().zip(row) {
                *sv += v;
            }
        }
    });
    s
}

/// Mean softmax cross-entropy over `rows` rows of `v` logits.
/// Returns (mean loss, dlogits = (softmax - onehot)/rows, n_correct).
///
/// Rows are independent, so the gradient parallelizes freely; the f64
/// loss and correct-count fold stays a sequential pass in row order over
/// the per-row stats, making the totals partition-independent.
pub fn softmax_xent(logits: &[f32], y: &[i32], rows: usize, v: usize) -> (f32, Vec<f32>, i32) {
    debug_assert_eq!(logits.len(), rows * v);
    debug_assert_eq!(y.len(), rows);
    let mut dl = vec![0.0f32; rows * v];
    let mut stats: Vec<(f64, i32)> = vec![(0.0, 0); rows];
    par::par_rows2(&mut dl, &mut stats, rows, rows * v * 8, |r0, dchunk, schunk| {
        for (rr, (drow, stat)) in dchunk.chunks_exact_mut(v).zip(schunk.iter_mut()).enumerate() {
            let i = r0 + rr;
            let row = &logits[i * v..(i + 1) * v];
            let gold = y[i] as usize;
            debug_assert!(gold < v, "label out of range");
            let mut mx = f32::NEG_INFINITY;
            let mut arg = 0usize;
            for (j, &x) in row.iter().enumerate() {
                if x > mx {
                    mx = x;
                    arg = j;
                }
            }
            let mut z = 0.0f32;
            for &x in row {
                z += (x - mx).exp();
            }
            for (dv, &x) in drow.iter_mut().zip(row) {
                *dv = (x - mx).exp() / z;
            }
            drow[gold] -= 1.0;
            *stat = ((z.ln() + mx - row[gold]) as f64, (arg == gold) as i32);
        }
    });
    let mut loss = 0.0f64;
    let mut correct = 0i32;
    for &(l, c) in &stats {
        loss += l;
        correct += c;
    }
    let inv = 1.0 / rows as f32;
    for d in dl.iter_mut() {
        *d *= inv;
    }
    ((loss / rows as f64) as f32, dl, correct)
}

/// LayerNorm forward over `rows` rows of width `d`.
/// Returns (y, xhat, rstd) — the backward caches.
pub fn ln_fwd(
    x: &[f32],
    scale: &[f32],
    bias: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; rows * d];
    let mut xhat = vec![0.0f32; rows * d];
    let mut rstd = vec![0.0f32; rows];
    let dn = d as f32;
    if d == 0 {
        return (y, xhat, rstd);
    }
    par::par_rows3(&mut y, &mut xhat, &mut rstd, rows, rows * d * 4, |r0, yc, xc, rc| {
        for (rr, ((yrow, xhrow), rs)) in yc
            .chunks_exact_mut(d)
            .zip(xc.chunks_exact_mut(d))
            .zip(rc.iter_mut())
            .enumerate()
        {
            let row = &x[(r0 + rr) * d..(r0 + rr + 1) * d];
            let mu = par::sum_lanes(row) / dn;
            let var = sumsq_diff_lanes(row, mu) / dn;
            let r = 1.0 / (var + LN_EPS).sqrt();
            *rs = r;
            for (j, (yv, xh)) in yrow.iter_mut().zip(xhrow.iter_mut()).enumerate() {
                let v = (row[j] - mu) * r;
                *xh = v;
                *yv = v * scale[j] + bias[j];
            }
        }
    });
    (y, xhat, rstd)
}

/// Sum of squared deviations with the fixed-lane order contract of
/// [`par::sum_lanes`].
fn sumsq_diff_lanes(row: &[f32], mu: f32) -> f32 {
    let mut acc = [0.0f32; par::LANES];
    let mut it = row.chunks_exact(par::LANES);
    for c in &mut it {
        for (s, &v) in acc.iter_mut().zip(c) {
            let dv = v - mu;
            *s += dv * dv;
        }
    }
    let mut tail = 0.0f32;
    for &v in it.remainder() {
        let dv = v - mu;
        tail += dv * dv;
    }
    par::reduce_lanes(&acc) + tail
}

/// LayerNorm backward. Returns (dx, dscale, dbias).
///
/// Two passes: `dx` is row-parallel (per-row means use the fixed-lane
/// order), `dscale`/`dbias` are column-parallel with rows accumulated in
/// ascending `i` — the scalar reference order per element.
pub fn ln_bwd(
    dy: &[f32],
    scale: &[f32],
    xhat: &[f32],
    rstd: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; rows * d];
    let mut dscale = vec![0.0f32; d];
    let mut dbias = vec![0.0f32; d];
    if d == 0 {
        return (dx, dscale, dbias);
    }
    let dn = d as f32;
    par::par_rows(&mut dx, rows, rows * d * 6, |r0, chunk| {
        for (rr, dxrow) in chunk.chunks_exact_mut(d).enumerate() {
            let i = r0 + rr;
            let dyrow = &dy[i * d..(i + 1) * d];
            let xrow = &xhat[i * d..(i + 1) * d];
            let mut accg = [0.0f32; par::LANES];
            let mut accgx = [0.0f32; par::LANES];
            let mut iy = dyrow.chunks_exact(par::LANES);
            let mut ix = xrow.chunks_exact(par::LANES);
            let mut isc = scale.chunks_exact(par::LANES);
            for ((cy, cx), cs) in (&mut iy).zip(&mut ix).zip(&mut isc) {
                for (((sg, sgx), (&dyv, &xh)), &sc) in accg
                    .iter_mut()
                    .zip(accgx.iter_mut())
                    .zip(cy.iter().zip(cx))
                    .zip(cs)
                {
                    let gg = dyv * sc;
                    *sg += gg;
                    *sgx += gg * xh;
                }
            }
            let mut tg = 0.0f32;
            let mut tgx = 0.0f32;
            let (ry, rx, rs) = (iy.remainder(), ix.remainder(), isc.remainder());
            for ((&dyv, &xh), &sc) in ry.iter().zip(rx).zip(rs) {
                let gg = dyv * sc;
                tg += gg;
                tgx += gg * xh;
            }
            let mg = (par::reduce_lanes(&accg) + tg) / dn;
            let mgx = (par::reduce_lanes(&accgx) + tgx) / dn;
            for (j, dv) in dxrow.iter_mut().enumerate() {
                let gg = dyrow[j] * scale[j];
                *dv = (gg - mg - xrow[j] * mgx) * rstd[i];
            }
        }
    });
    par::par_rows2(&mut dscale, &mut dbias, d, rows * d * 2, |c0, sc_chunk, sb_chunk| {
        for i in 0..rows {
            let dyrow = &dy[i * d + c0..i * d + c0 + sc_chunk.len()];
            let xrow = &xhat[i * d + c0..i * d + c0 + sc_chunk.len()];
            for ((sv, bv), (&dyv, &xh)) in sc_chunk
                .iter_mut()
                .zip(sb_chunk.iter_mut())
                .zip(dyrow.iter().zip(xrow))
            {
                *sv += dyv * xh;
                *bv += dyv;
            }
        }
    });
    (dx, dscale, dbias)
}

/// GELU (tanh approximation) forward; returns (y, tanh cache).
pub fn gelu_fwd(x: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let c0 = (2.0f32 / std::f32::consts::PI).sqrt();
    let mut y = vec![0.0f32; x.len()];
    let mut t = vec![0.0f32; x.len()];
    par::par_rows2(&mut y, &mut t, x.len(), x.len() * 16, |e0, yc, tc| {
        let xs = &x[e0..e0 + yc.len()];
        for ((yv, tv), &v) in yc.iter_mut().zip(tc.iter_mut()).zip(xs) {
            let u = c0 * (v + 0.044715 * v * v * v);
            let th = u.tanh();
            *tv = th;
            *yv = 0.5 * v * (1.0 + th);
        }
    });
    (y, t)
}

/// GELU backward: dy -> dx, given the input x and the tanh cache.
pub fn gelu_bwd(dy: &[f32], x: &[f32], t: &[f32]) -> Vec<f32> {
    let c0 = (2.0f32 / std::f32::consts::PI).sqrt();
    let mut dx = vec![0.0f32; x.len()];
    par::par_rows(&mut dx, x.len(), x.len() * 8, |e0, chunk| {
        for (i, dv) in chunk.iter_mut().enumerate() {
            let v = x[e0 + i];
            let th = t[e0 + i];
            let du = c0 * (1.0 + 3.0 * 0.044715 * v * v);
            *dv = dy[e0 + i] * (0.5 * (1.0 + th) + 0.5 * v * (1.0 - th * th) * du);
        }
    });
    dx
}

/// Parameter slice by segment name.
fn p<'a>(w: &'a [f32], segs: &SegmentTable, name: &str) -> &'a [f32] {
    let s = segs
        .by_name(name)
        .unwrap_or_else(|| panic!("missing parameter segment {name:?}"));
    &w[s.offset..s.offset + s.size]
}

/// Accumulate a gradient slice by segment name.
fn add_grad(g: &mut [f32], segs: &SegmentTable, name: &str, src: &[f32]) {
    let s = segs
        .by_name(name)
        .unwrap_or_else(|| panic!("missing parameter segment {name:?}"));
    assert_eq!(s.size, src.len(), "gradient size mismatch for {name:?}");
    let dst = &mut g[s.offset..s.offset + s.size];
    for (d, v) in dst.iter_mut().zip(src) {
        *d += v;
    }
}

// ---------------------------------------------------------------------------
// Residual MLP (the "ResNet" stand-in)
// ---------------------------------------------------------------------------

/// Mirror of `MlpConfig` + `mlp_logits` in python/compile/model.py.
#[derive(Debug, Clone)]
pub struct MlpModel {
    pub batch: usize,
    pub input_dim: usize,
    pub hidden: usize,
    pub blocks: usize,
    pub classes: usize,
}

struct MlpForward {
    /// hs[0] = relu of the input layer; hs[i+1] = block i output.
    hs: Vec<Vec<f32>>,
    /// Per-block relu(z1) activations.
    z1s: Vec<Vec<f32>>,
    logits: Vec<f32>,
}

impl MlpModel {
    fn forward(&self, segs: &SegmentTable, w: &[f32], x: &[f32]) -> MlpForward {
        let (b, d, h, c) = (self.batch, self.input_dim, self.hidden, self.classes);
        let mut h0 = matmul(x, p(w, segs, "in.w"), b, d, h);
        add_bias(&mut h0, p(w, segs, "in.b"), b, h);
        for v in h0.iter_mut() {
            *v = v.max(0.0);
        }
        let mut hs = vec![h0];
        let mut z1s = Vec::with_capacity(self.blocks);
        for i in 0..self.blocks {
            let (z1, hout) = {
                let hin = &hs[i];
                let mut a1 = matmul(hin, p(w, segs, &format!("block{i}.w1")), b, h, h);
                add_bias(&mut a1, p(w, segs, &format!("block{i}.b1")), b, h);
                for v in a1.iter_mut() {
                    *v = v.max(0.0);
                }
                let mut a2 = matmul(&a1, p(w, segs, &format!("block{i}.w2")), b, h, h);
                add_bias(&mut a2, p(w, segs, &format!("block{i}.b2")), b, h);
                for (j, v) in a2.iter_mut().enumerate() {
                    *v = (hin[j] + *v).max(0.0);
                }
                (a1, a2)
            };
            z1s.push(z1);
            hs.push(hout);
        }
        let mut logits = matmul(&hs[self.blocks], p(w, segs, "head.w"), b, h, c);
        add_bias(&mut logits, p(w, segs, "head.b"), b, c);
        MlpForward { hs, z1s, logits }
    }

    pub fn grad_step(
        &self,
        segs: &SegmentTable,
        w: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> (f32, Vec<f32>) {
        let (b, d, h, c) = (self.batch, self.input_dim, self.hidden, self.classes);
        let fwd = self.forward(segs, w, x);
        let (loss, dl, _) = softmax_xent(&fwd.logits, y, b, c);

        let mut g = vec![0.0f32; segs.total_size()];
        add_grad(&mut g, segs, "head.w", &matmul_tn(&fwd.hs[self.blocks], &dl, b, h, c));
        add_grad(&mut g, segs, "head.b", &col_sum(&dl, b, c));
        let mut dh = matmul_nt(&dl, p(w, segs, "head.w"), b, c, h);
        for i in (0..self.blocks).rev() {
            let hin = &fwd.hs[i];
            let hout = &fwd.hs[i + 1];
            let z1 = &fwd.z1s[i];
            // h_out = relu(h_in + a2): mask the residual-sum gradient.
            let mut dsum = dh.clone();
            for j in 0..b * h {
                if hout[j] <= 0.0 {
                    dsum[j] = 0.0;
                }
            }
            let w2 = p(w, segs, &format!("block{i}.w2"));
            add_grad(&mut g, segs, &format!("block{i}.w2"), &matmul_tn(z1, &dsum, b, h, h));
            add_grad(&mut g, segs, &format!("block{i}.b2"), &col_sum(&dsum, b, h));
            let mut da1 = matmul_nt(&dsum, w2, b, h, h);
            for j in 0..b * h {
                if z1[j] <= 0.0 {
                    da1[j] = 0.0;
                }
            }
            let w1 = p(w, segs, &format!("block{i}.w1"));
            add_grad(&mut g, segs, &format!("block{i}.w1"), &matmul_tn(hin, &da1, b, h, h));
            add_grad(&mut g, segs, &format!("block{i}.b1"), &col_sum(&da1, b, h));
            let dh_prev = matmul_nt(&da1, w1, b, h, h);
            for j in 0..b * h {
                dh[j] = dsum[j] + dh_prev[j];
            }
        }
        let h0 = &fwd.hs[0];
        let mut da = dh;
        for j in 0..b * h {
            if h0[j] <= 0.0 {
                da[j] = 0.0;
            }
        }
        add_grad(&mut g, segs, "in.w", &matmul_tn(x, &da, b, d, h));
        add_grad(&mut g, segs, "in.b", &col_sum(&da, b, h));
        (loss, g)
    }

    pub fn eval_step(&self, segs: &SegmentTable, w: &[f32], x: &[f32], y: &[i32]) -> (f32, i32) {
        let fwd = self.forward(segs, w, x);
        let (loss, _, correct) = softmax_xent(&fwd.logits, y, self.batch, self.classes);
        (loss, correct)
    }
}

// ---------------------------------------------------------------------------
// Decoder-only transformer LM (tied embedding head)
// ---------------------------------------------------------------------------

/// Mirror of `TransformerConfig` + `transformer_logits` in model.py.
#[derive(Debug, Clone)]
pub struct TransformerModel {
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
}

struct LayerCache {
    ln1: Vec<f32>,
    xhat1: Vec<f32>,
    rstd1: Vec<f32>,
    qkv: Vec<f32>,
    /// [b, heads, s, s] attention probabilities (0 above the diagonal).
    prob: Vec<f32>,
    o: Vec<f32>,
    ln2: Vec<f32>,
    xhat2: Vec<f32>,
    rstd2: Vec<f32>,
    a_ff: Vec<f32>,
    tanh: Vec<f32>,
    gl: Vec<f32>,
}

struct TfForward {
    layers: Vec<LayerCache>,
    xf: Vec<f32>,
    xhat_f: Vec<f32>,
    rstd_f: Vec<f32>,
    logits: Vec<f32>,
}

impl TransformerModel {
    fn forward(&self, segs: &SegmentTable, w: &[f32], tokens: &[i32]) -> TfForward {
        let (b, s, d, hn, f, v) = (
            self.batch,
            self.seq,
            self.d_model,
            self.n_heads,
            self.d_ff,
            self.vocab,
        );
        let hd = d / hn;
        let inv = 1.0 / (hd as f32).sqrt();
        let bs = b * s;
        let embed = p(w, segs, "embed");
        let pos = p(w, segs, "pos");

        let mut x = vec![0.0f32; bs * d];
        for i in 0..bs {
            let t = tokens[i] as usize;
            debug_assert!(t < v, "token out of range");
            let si = i % s;
            for dd in 0..d {
                x[i * d + dd] = embed[t * d + dd] + pos[si * d + dd];
            }
        }

        let mut layers = Vec::with_capacity(self.n_layers);
        for li in 0..self.n_layers {
            let (ln1, xhat1, rstd1) = ln_fwd(
                &x,
                p(w, segs, &format!("layer{li}.ln1.scale")),
                p(w, segs, &format!("layer{li}.ln1.bias")),
                bs,
                d,
            );
            let qkv = matmul(&ln1, p(w, segs, &format!("layer{li}.qkv")), bs, d, 3 * d);
            let mut prob = vec![0.0f32; b * hn * s * s];
            let mut o = vec![0.0f32; bs * d];
            // Batch-parallel: every write for batch element bb lands in
            // bb's own prob/o rows, and the per-(head, query) math is
            // untouched, so the partitioning cannot change results. The
            // score scratch is one allocation per chunk, not per query.
            let aw = b * hn * s * s * hd * 2;
            par::par_rows2(&mut prob, &mut o, b, aw, |b0, pchunk, ochunk| {
                let mut sc = vec![0.0f32; s];
                let pb = pchunk.chunks_exact_mut(hn * s * s);
                for (bi, (pbb, obb)) in pb.zip(ochunk.chunks_exact_mut(s * d)).enumerate() {
                    let bb = b0 + bi;
                    for h in 0..hn {
                        for qi in 0..s {
                            let qoff = (bb * s + qi) * 3 * d + h * hd;
                            let q = &qkv[qoff..qoff + hd];
                            let row = &mut sc[..qi + 1];
                            let mut mx = f32::NEG_INFINITY;
                            for (ki, rv) in row.iter_mut().enumerate() {
                                let koff = (bb * s + ki) * 3 * d + d + h * hd;
                                *rv = par::dot_lanes(q, &qkv[koff..koff + hd]) * inv;
                                mx = mx.max(*rv);
                            }
                            let mut z = 0.0f32;
                            for rv in row.iter_mut() {
                                *rv = (*rv - mx).exp();
                                z += *rv;
                            }
                            let pr = &mut pbb[(h * s + qi) * s..][..s];
                            for (ki, rv) in row.iter().enumerate() {
                                pr[ki] = rv / z;
                            }
                            // o-row accumulation as an axpy over ki: per
                            // element e the additions stay in ascending
                            // ki, matching the scalar dot formulation.
                            let orow = &mut obb[qi * d + h * hd..qi * d + h * hd + hd];
                            for (ki, &pv) in pr[..=qi].iter().enumerate() {
                                let voff = (bb * s + ki) * 3 * d + 2 * d + h * hd;
                                for (ov, &vv) in orow.iter_mut().zip(&qkv[voff..voff + hd]) {
                                    *ov += pv * vv;
                                }
                            }
                        }
                    }
                }
            });
            let attn = matmul(&o, p(w, segs, &format!("layer{li}.attn_out")), bs, d, d);
            let mut x1 = x;
            for j in 0..bs * d {
                x1[j] += attn[j];
            }
            let (ln2, xhat2, rstd2) = ln_fwd(
                &x1,
                p(w, segs, &format!("layer{li}.ln2.scale")),
                p(w, segs, &format!("layer{li}.ln2.bias")),
                bs,
                d,
            );
            let mut a_ff = matmul(&ln2, p(w, segs, &format!("layer{li}.ff1")), bs, d, f);
            add_bias(&mut a_ff, p(w, segs, &format!("layer{li}.ff1_b")), bs, f);
            let (gl, tanh) = gelu_fwd(&a_ff);
            let ff_out = matmul(&gl, p(w, segs, &format!("layer{li}.ff2")), bs, f, d);
            let ff2_b = p(w, segs, &format!("layer{li}.ff2_b"));
            let mut x2 = x1;
            for i in 0..bs {
                for dd in 0..d {
                    x2[i * d + dd] += ff_out[i * d + dd] + ff2_b[dd];
                }
            }
            layers.push(LayerCache {
                ln1,
                xhat1,
                rstd1,
                qkv,
                prob,
                o,
                ln2,
                xhat2,
                rstd2,
                a_ff,
                tanh,
                gl,
            });
            x = x2;
        }
        let (xf, xhat_f, rstd_f) =
            ln_fwd(&x, p(w, segs, "lnf.scale"), p(w, segs, "lnf.bias"), bs, d);
        // Tied head: logits = xf @ embed^T — the shared NT kernel.
        let logits = matmul_nt(&xf, embed, bs, d, v);
        TfForward { layers, xf, xhat_f, rstd_f, logits }
    }

    pub fn grad_step(
        &self,
        segs: &SegmentTable,
        w: &[f32],
        tokens: &[i32],
        y: &[i32],
    ) -> (f32, Vec<f32>) {
        let (b, s, d, hn, f, v) = (
            self.batch,
            self.seq,
            self.d_model,
            self.n_heads,
            self.d_ff,
            self.vocab,
        );
        let hd = d / hn;
        let inv = 1.0 / (hd as f32).sqrt();
        let bs = b * s;
        let embed = p(w, segs, "embed");
        let fwd = self.forward(segs, w, tokens);
        let (loss, dl, _) = softmax_xent(&fwd.logits, y, bs, v);

        let mut g = vec![0.0f32; segs.total_size()];

        // Tied head: g_embed += dl^T @ xf; dxf = dl @ embed. Both are
        // the shared kernels, whose per-element accumulation order (and
        // zero-skip) matches the fused loop they replace.
        let mut g_embed = matmul_tn(&dl, &fwd.xf, bs, v, d);
        let dxf = matmul(&dl, embed, bs, v, d);
        let (mut dx, dsc, dbi) = ln_bwd(
            &dxf,
            p(w, segs, "lnf.scale"),
            &fwd.xhat_f,
            &fwd.rstd_f,
            bs,
            d,
        );
        add_grad(&mut g, segs, "lnf.scale", &dsc);
        add_grad(&mut g, segs, "lnf.bias", &dbi);

        for li in (0..self.n_layers).rev() {
            let c = &fwd.layers[li];
            // x2 = x1 + gelu(ln2 @ ff1 + b1) @ ff2 + b2
            let ff2 = p(w, segs, &format!("layer{li}.ff2"));
            let dgl = matmul_nt(&dx, ff2, bs, d, f);
            add_grad(&mut g, segs, &format!("layer{li}.ff2"), &matmul_tn(&c.gl, &dx, bs, f, d));
            add_grad(&mut g, segs, &format!("layer{li}.ff2_b"), &col_sum(&dx, bs, d));
            let da = gelu_bwd(&dgl, &c.a_ff, &c.tanh);
            add_grad(&mut g, segs, &format!("layer{li}.ff1"), &matmul_tn(&c.ln2, &da, bs, d, f));
            add_grad(&mut g, segs, &format!("layer{li}.ff1_b"), &col_sum(&da, bs, f));
            let ff1 = p(w, segs, &format!("layer{li}.ff1"));
            let dln2 = matmul_nt(&da, ff1, bs, f, d);
            let (mut dx1, dsc, dbi) = ln_bwd(
                &dln2,
                p(w, segs, &format!("layer{li}.ln2.scale")),
                &c.xhat2,
                &c.rstd2,
                bs,
                d,
            );
            add_grad(&mut g, segs, &format!("layer{li}.ln2.scale"), &dsc);
            add_grad(&mut g, segs, &format!("layer{li}.ln2.bias"), &dbi);
            for j in 0..bs * d {
                dx1[j] += dx[j]; // residual around the FF block
            }
            // x1 = x0 + o @ attn_out
            let attn_out = p(w, segs, &format!("layer{li}.attn_out"));
            let do_ = matmul_nt(&dx1, attn_out, bs, d, d);
            add_grad(
                &mut g,
                segs,
                &format!("layer{li}.attn_out"),
                &matmul_tn(&c.o, &dx1, bs, d, d),
            );
            // Attention core: do_ -> dqkv. Batch-parallel like the
            // forward — every dqkv write for batch element bb stays in
            // bb's own rows, so partitioning cannot race or reorder.
            let mut dqkv = vec![0.0f32; bs * 3 * d];
            let aw = b * hn * s * s * hd * 4;
            par::par_rows(&mut dqkv, b, aw, |b0, chunk| {
                let mut dps = vec![0.0f32; s];
                for (bi, dqb) in chunk.chunks_exact_mut(s * 3 * d).enumerate() {
                    let bb = b0 + bi;
                    for h in 0..hn {
                        for qi in 0..s {
                            let pr = &c.prob[((bb * hn + h) * s + qi) * s..][..s];
                            let dorow = &do_[(bb * s + qi) * d + h * hd..][..hd];
                            // dprob and sum(dprob * prob) over the causal range.
                            let dp = &mut dps[..qi + 1];
                            let mut sum_dp_p = 0.0f32;
                            for (ki, dpv) in dp.iter_mut().enumerate() {
                                let voff = (bb * s + ki) * 3 * d + 2 * d + h * hd;
                                let acc = par::dot_lanes(dorow, &c.qkv[voff..voff + hd]);
                                *dpv = acc;
                                sum_dp_p += acc * pr[ki];
                            }
                            for ki in 0..=qi {
                                // dv[ki] += prob * do
                                let pv = pr[ki];
                                if pv != 0.0 {
                                    let dvrel = ki * 3 * d + 2 * d + h * hd;
                                    let dvrow = &mut dqb[dvrel..dvrel + hd];
                                    for (dv, &dov) in dvrow.iter_mut().zip(dorow) {
                                        *dv += pv * dov;
                                    }
                                }
                                // dscore (softmax backward), with the 1/sqrt(hd)
                                // factor folded in once for both dq and dk.
                                let ds = pv * (dp[ki] - sum_dp_p) * inv;
                                if ds != 0.0 {
                                    let qoff = (bb * s + qi) * 3 * d + h * hd;
                                    let koff = (bb * s + ki) * 3 * d + d + h * hd;
                                    let qrel = qi * 3 * d + h * hd;
                                    let krel = ki * 3 * d + d + h * hd;
                                    for e in 0..hd {
                                        dqb[qrel + e] += ds * c.qkv[koff + e];
                                        dqb[krel + e] += ds * c.qkv[qoff + e];
                                    }
                                }
                            }
                        }
                    }
                }
            });
            add_grad(
                &mut g,
                segs,
                &format!("layer{li}.qkv"),
                &matmul_tn(&c.ln1, &dqkv, bs, d, 3 * d),
            );
            let wqkv = p(w, segs, &format!("layer{li}.qkv"));
            let dln1 = matmul_nt(&dqkv, wqkv, bs, 3 * d, d);
            let (dx0, dsc, dbi) = ln_bwd(
                &dln1,
                p(w, segs, &format!("layer{li}.ln1.scale")),
                &c.xhat1,
                &c.rstd1,
                bs,
                d,
            );
            add_grad(&mut g, segs, &format!("layer{li}.ln1.scale"), &dsc);
            add_grad(&mut g, segs, &format!("layer{li}.ln1.bias"), &dbi);
            for j in 0..bs * d {
                dx[j] = dx0[j] + dx1[j]; // residual around attention
            }
        }

        // x = embed[tokens] + pos
        let mut g_pos = vec![0.0f32; s * d];
        for i in 0..bs {
            let t = tokens[i] as usize;
            let si = i % s;
            for dd in 0..d {
                g_embed[t * d + dd] += dx[i * d + dd];
                g_pos[si * d + dd] += dx[i * d + dd];
            }
        }
        add_grad(&mut g, segs, "embed", &g_embed);
        add_grad(&mut g, segs, "pos", &g_pos);
        (loss, g)
    }

    pub fn eval_step(
        &self,
        segs: &SegmentTable,
        w: &[f32],
        tokens: &[i32],
        y: &[i32],
    ) -> (f32, i32) {
        let fwd = self.forward(segs, w, tokens);
        let (loss, _, correct) = softmax_xent(&fwd.logits, y, self.batch * self.seq, self.vocab);
        (loss, correct)
    }
}

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

/// A model variant executable natively on the CPU.
#[derive(Debug, Clone)]
pub enum NativeModel {
    Mlp(MlpModel),
    Transformer(TransformerModel),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Model, Runtime, XData};
    use crate::util::Rng;
    use std::path::PathBuf;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Central finite differences on the highest-|grad| coordinates: the
    /// backward pass must agree with the loss surface it claims to
    /// differentiate. Probing the largest entries keeps the f32 forward
    /// noise well below the measured delta.
    fn finite_diff_check(model: &Model, x: &XData, y: &[i32]) {
        let mut w = model.meta.init_params().unwrap();
        // Perturb away from the symmetric init so grads are generic.
        let mut rng = Rng::new(0xFD);
        for v in w.iter_mut() {
            *v += 0.02 * rng.normal() as f32;
        }
        let (_, grads) = model.grad_step(&w, x, y).unwrap();
        let mut idx: Vec<usize> = (0..grads.len()).collect();
        idx.sort_by(|&a, &b| grads[b].abs().total_cmp(&grads[a].abs()));
        let eps = 1e-2f32;
        for &i in idx.iter().take(16) {
            let orig = w[i];
            w[i] = orig + eps;
            let (lp, _) = model.grad_step(&w, x, y).unwrap();
            w[i] = orig - eps;
            let (lm, _) = model.grad_step(&w, x, y).unwrap();
            w[i] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let g = grads[i];
            assert!(
                (fd - g).abs() <= 0.05 * g.abs().max(0.05),
                "param {i}: fd {fd} vs grad {g}"
            );
        }
    }

    #[test]
    fn mlp_grad_matches_finite_difference() {
        let rt = Runtime::cpu().unwrap();
        let model = Model::load(&rt, &artifacts(), "mlp_tiny").unwrap();
        let batch = model.meta.batch_size();
        let dim = model.meta.x_shape[1] as usize;
        let data = crate::data::GaussianMixture::new(dim, 4, 0.5, 11);
        let b = data.batch(0, batch);
        finite_diff_check(&model, &XData::F32(b.x), &b.y);
    }

    #[test]
    fn transformer_grad_matches_finite_difference() {
        let rt = Runtime::cpu().unwrap();
        let model = Model::load(&rt, &artifacts(), "transformer_tiny").unwrap();
        let batch = model.meta.batch_size();
        let seq = model.meta.x_shape[1] as usize;
        let corpus = crate::data::TinyCorpus::new(64, 5);
        let (x, y) = corpus.batch_tokens(0, batch, seq);
        finite_diff_check(&model, &XData::I32(x), &y);
    }

    #[test]
    fn transformer_init_loss_near_uniform() {
        let rt = Runtime::cpu().unwrap();
        let model = Model::load(&rt, &artifacts(), "transformer_tiny").unwrap();
        let w = model.meta.init_params().unwrap();
        let corpus = crate::data::TinyCorpus::new(64, 5);
        let (x, y) = corpus.batch_tokens(0, model.meta.batch_size(), model.meta.x_shape[1] as usize);
        let (loss, _) = model.eval_step(&w, &XData::I32(x), &y).unwrap();
        assert!((loss - 64f32.ln()).abs() < 0.5, "init loss {loss}");
    }

    #[test]
    fn softmax_xent_uniform_and_onehot() {
        // Uniform logits: loss = ln(v), grad rows sum to 0.
        let (loss, dl, _) = softmax_xent(&[0.0; 8], &[3, 1], 2, 4);
        assert!((loss - 4.0f32.ln()).abs() < 1e-6);
        for i in 0..2 {
            let s: f32 = dl[i * 4..(i + 1) * 4].iter().sum();
            assert!(s.abs() < 1e-6);
        }
        // Confident correct logit: near-zero loss.
        let (loss, _, correct) = softmax_xent(&[20.0, 0.0, 0.0, 0.0], &[0], 1, 4);
        assert!(loss < 1e-3);
        assert_eq!(correct, 1);
    }

    #[test]
    fn layernorm_output_normalized() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let (y, _, _) = ln_fwd(&x, &[1.0; 4], &[0.0; 4], 1, 4);
        let mean: f32 = y.iter().sum::<f32>() / 4.0;
        let var: f32 = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn matmul_shapes_and_values() {
        // [2,3] @ [3,2]
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let y = matmul(&x, &w, 2, 3, 2);
        assert_eq!(y, vec![4.0, 5.0, 10.0, 11.0]);
        // Gradient identities: d(x@w)/dw with dy=1 equals column sums of x.
        let dy = vec![1.0; 4];
        let gw = matmul_tn(&x, &dy, 2, 3, 2);
        assert_eq!(gw, vec![5.0, 5.0, 7.0, 7.0, 9.0, 9.0]);
        let dx = matmul_nt(&dy, &w, 2, 2, 3);
        assert_eq!(dx, vec![1.0, 1.0, 2.0, 1.0, 1.0, 2.0]);
    }
}
