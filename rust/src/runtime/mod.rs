//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! This is the only place Python's output touches Rust: `make artifacts`
//! lowers the L2/L1 JAX+Pallas stack to `artifacts/*.hlo.txt`; here we
//! parse that text into an `HloModuleProto`, compile it on the PJRT CPU
//! client and execute it from the training hot path. Text (never
//! `.serialize()`d protos) is the interchange format — jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids.
//!
//! `PjRtClient` is `Rc`-backed (not `Send`), so multi-threaded users go
//! through [`service::ModelService`], a dedicated thread that owns every
//! executable (the "device service" — the analog of the GPUs all workers
//! on a node share).

pub mod service;

use crate::jsonlite::{self, Value};
use crate::tensor::{Segment, SegmentTable};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled HLO module ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// Typed input buffer for [`Executable::run`].
pub enum Input<'a> {
    F32(&'a [f32], &'a [i64]),
    I32(&'a [i32], &'a [i64]),
}

impl Input<'_> {
    /// Upload to a device buffer. We deliberately avoid
    /// `PjRtLoadedExecutable::execute` (xla 0.1.6 leaks every input device
    /// buffer it creates from host literals — `release()` without a
    /// matching free in `xla_rs.cc::execute`); `buffer_from_host_buffer` +
    /// `execute_b` keeps ownership on the Rust side, where `PjRtBuffer`'s
    /// `Drop` frees it.
    fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        let dims_usize = |dims: &[i64]| dims.iter().map(|&d| d as usize).collect::<Vec<_>>();
        Ok(match self {
            Input::F32(data, dims) => {
                client.buffer_from_host_buffer(data, &dims_usize(dims), None)?
            }
            Input::I32(data, dims) => {
                client.buffer_from_host_buffer(data, &dims_usize(dims), None)?
            }
        })
    }
}

impl Executable {
    /// Execute with host inputs; returns the elements of the root tuple
    /// (aot.py lowers everything with `return_tuple=True`).
    pub fn run(&self, inputs: &[Input<'_>]) -> Result<Vec<xla::Literal>> {
        let client = self.exe.client();
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|i| i.to_buffer(client))
            .collect::<Result<_>>()?;
        let out = self.exe.execute_b::<xla::PjRtBuffer>(&bufs)?;
        let root = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        Ok(root.to_tuple()?)
    }
}

/// The PJRT CPU client + executable loader.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

// ---------------------------------------------------------------------------
// Model metadata (artifacts/meta.json)
// ---------------------------------------------------------------------------

/// Input batch for a model variant: dense features or token ids.
#[derive(Debug, Clone)]
pub enum XData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Parsed per-variant metadata from `meta.json`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub variant: String,
    pub params: usize,
    pub x_shape: Vec<i64>,
    pub x_dtype: String,
    pub y_shape: Vec<i64>,
    pub segments: SegmentTable,
    pub artifacts: HashMap<String, String>,
    /// The variant's Python-side config dict (vocab, hidden, classes, ...).
    pub config: Value,
    pub dir: PathBuf,
}

impl ModelMeta {
    /// Load variant metadata from `artifacts/meta.json`.
    pub fn load(artifacts_dir: &Path, variant: &str) -> Result<Self> {
        let meta = jsonlite::parse_file(&artifacts_dir.join("meta.json"))?;
        let v = meta
            .req("variants")?
            .get(variant)
            .with_context(|| format!("variant {variant:?} not in meta.json"))?;
        let shape = |spec: &Value| -> Result<Vec<i64>> {
            Ok(spec
                .req("shape")?
                .as_arr()
                .context("shape not array")?
                .iter()
                .map(|x| x.as_f64().unwrap_or(0.0) as i64)
                .collect())
        };
        let segments = SegmentTable::new(
            v.req("segments")?
                .as_arr()
                .context("segments not array")?
                .iter()
                .map(|s| -> Result<Segment> {
                    Ok(Segment {
                        name: s.req("name")?.as_str().context("name")?.to_string(),
                        offset: s.req("offset")?.as_usize().context("offset")?,
                        size: s.req("size")?.as_usize().context("size")?,
                        shape: s
                            .req("shape")?
                            .as_arr()
                            .context("shape")?
                            .iter()
                            .map(|x| x.as_usize().unwrap_or(0))
                            .collect(),
                    })
                })
                .collect::<Result<_>>()?,
        );
        segments.validate()?;
        let artifacts = v
            .req("artifacts")?
            .as_obj()
            .context("artifacts not object")?
            .iter()
            .map(|(k, val)| (k.clone(), val.as_str().unwrap_or("").to_string()))
            .collect();
        Ok(Self {
            variant: variant.to_string(),
            params: v.req("params")?.as_usize().context("params")?,
            x_shape: shape(v.req("x")?)?,
            x_dtype: v.req("x")?.req("dtype")?.as_str().context("dtype")?.to_string(),
            y_shape: shape(v.req("y")?)?,
            segments,
            artifacts,
            config: v.get("config").cloned().unwrap_or(Value::Null),
            dir: artifacts_dir.to_path_buf(),
        })
    }

    /// Numeric field of the variant config (e.g. "vocab", "classes").
    pub fn config_num(&self, key: &str) -> Option<f64> {
        self.config.get(key).and_then(|v| v.as_f64())
    }

    pub fn batch_size(&self) -> usize {
        self.x_shape.first().copied().unwrap_or(0) as usize
    }

    pub fn artifact_path(&self, kind: &str) -> Result<PathBuf> {
        let f = self
            .artifacts
            .get(kind)
            .with_context(|| format!("artifact kind {kind:?} missing"))?;
        Ok(self.dir.join(f))
    }

    /// Read the deterministic initial flat parameter vector.
    pub fn init_params(&self) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.artifact_path("init")?)?;
        anyhow::ensure!(bytes.len() == self.params * 4, "init.bin size mismatch");
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

// ---------------------------------------------------------------------------
// Model: all executables of one variant, single-threaded
// ---------------------------------------------------------------------------

/// All compiled entry points for one model variant (single-thread use; see
/// [`service::ModelService`] for the shared-thread version).
pub struct Model {
    pub meta: ModelMeta,
    grad: Executable,
    eval: Executable,
    sgd: Executable,
    elastic1: Executable,
    elastic2: Executable,
}

impl Model {
    pub fn load(rt: &Runtime, artifacts_dir: &Path, variant: &str) -> Result<Self> {
        let meta = ModelMeta::load(artifacts_dir, variant)?;
        Ok(Self {
            grad: rt.load_hlo(&meta.artifact_path("grad")?)?,
            eval: rt.load_hlo(&meta.artifact_path("eval")?)?,
            sgd: rt.load_hlo(&meta.artifact_path("sgd")?)?,
            elastic1: rt.load_hlo(&meta.artifact_path("elastic1")?)?,
            elastic2: rt.load_hlo(&meta.artifact_path("elastic2")?)?,
            meta,
        })
    }

    fn x_input<'a>(&'a self, x: &'a XData) -> Result<Input<'a>> {
        Ok(match x {
            XData::F32(d) => {
                anyhow::ensure!(self.meta.x_dtype == "float32", "x dtype mismatch");
                Input::F32(d, &self.meta.x_shape)
            }
            XData::I32(d) => {
                anyhow::ensure!(self.meta.x_dtype == "int32", "x dtype mismatch");
                Input::I32(d, &self.meta.x_shape)
            }
        })
    }

    /// Forward+backward: returns (loss, flat gradients).
    pub fn grad_step(&self, params: &[f32], x: &XData, y: &[i32]) -> Result<(f32, Vec<f32>)> {
        let n = self.meta.params as i64;
        let out = self.grad.run(&[
            Input::F32(params, &[n]),
            self.x_input(x)?,
            Input::I32(y, &self.meta.y_shape),
        ])?;
        let loss = out[0].get_first_element::<f32>()?;
        let grads = out[1].to_vec::<f32>()?;
        Ok((loss, grads))
    }

    /// Evaluation: returns (loss, #correct predictions in batch).
    pub fn eval_step(&self, params: &[f32], x: &XData, y: &[i32]) -> Result<(f32, i32)> {
        let n = self.meta.params as i64;
        let out = self.eval.run(&[
            Input::F32(params, &[n]),
            self.x_input(x)?,
            Input::I32(y, &self.meta.y_shape),
        ])?;
        Ok((
            out[0].get_first_element::<f32>()?,
            out[1].get_first_element::<i32>()?,
        ))
    }

    /// Fused SGD update via the compiled Pallas kernel:
    /// `(w, m) <- sgd(hyper, w, g, m)`.
    pub fn sgd_update(
        &self,
        w: &mut Vec<f32>,
        g: &[f32],
        m: &mut Vec<f32>,
        hyper: &crate::optimizer::SgdHyper,
    ) -> Result<()> {
        let n = self.meta.params as i64;
        let h = hyper.as_vec();
        let out = self.sgd.run(&[
            Input::F32(&h, &[4]),
            Input::F32(w, &[n]),
            Input::F32(g, &[n]),
            Input::F32(m, &[n]),
        ])?;
        *w = out[0].to_vec::<f32>()?;
        *m = out[1].to_vec::<f32>()?;
        Ok(())
    }

    /// Server-side elastic update (eq. 2): `center <- elastic1(alpha, center, w)`.
    pub fn elastic1(&self, center: &mut Vec<f32>, w: &[f32], alpha: f32) -> Result<()> {
        let n = self.meta.params as i64;
        let out = self.elastic1.run(&[
            Input::F32(&[alpha], &[1]),
            Input::F32(center, &[n]),
            Input::F32(w, &[n]),
        ])?;
        *center = out[0].to_vec::<f32>()?;
        Ok(())
    }

    /// Client-side elastic update (eq. 3): `w <- elastic2(alpha, w, center)`.
    pub fn elastic2(&self, w: &mut Vec<f32>, center: &[f32], alpha: f32) -> Result<()> {
        let n = self.meta.params as i64;
        let out = self.elastic2.run(&[
            Input::F32(&[alpha], &[1]),
            Input::F32(w, &[n]),
            Input::F32(center, &[n]),
        ])?;
        *w = out[0].to_vec::<f32>()?;
        Ok(())
    }
}
