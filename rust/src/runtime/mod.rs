//! Model runtime: load AOT artifacts (`meta.json` + `*_init.bin`) and
//! execute the model variants.
//!
//! Historically this was a PJRT bridge that compiled HLO text lowered by
//! `python/compile/aot.py`. The offline build image has neither the `xla`
//! crate nor a network to fetch one, so execution now goes through
//! [`native`]: hand-written CPU kernels mirroring the JAX models
//! bit-for-bit in architecture and loss convention (validated against
//! `jax.value_and_grad`, see `native.rs`). The artifact *interface* is
//! unchanged — `meta.json` still carries shapes, per-layer segments
//! (KVStore keys) and the deterministic `init.bin` produced by the Python
//! side — so `make artifacts` regenerating them stays compatible.
//!
//! Worker threads share one model through [`service::ModelService`], the
//! analog of the node's device queue (all DL workers of a node share its
//! GPUs in the paper).

pub mod native;
pub mod par;
pub mod service;

use crate::jsonlite::{self, Value};
use crate::tensor::{Segment, SegmentTable};
use anyhow::{bail, Context, Result};
use native::{MlpModel, NativeModel, TransformerModel};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// The execution backend handle. Kept as an explicit object so the PJRT
/// client can slot back in behind the same API when the toolchain has it.
pub struct Runtime;

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self)
    }

    pub fn platform(&self) -> String {
        "native-cpu".to_string()
    }
}

// ---------------------------------------------------------------------------
// Model metadata (artifacts/meta.json)
// ---------------------------------------------------------------------------

/// Input batch for a model variant: dense features or token ids.
#[derive(Debug, Clone)]
pub enum XData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Parsed per-variant metadata from `meta.json`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub variant: String,
    /// Python config class name ("MlpConfig" / "TransformerConfig").
    pub kind: String,
    pub params: usize,
    pub x_shape: Vec<i64>,
    pub x_dtype: String,
    pub y_shape: Vec<i64>,
    pub segments: SegmentTable,
    pub artifacts: HashMap<String, String>,
    /// The variant's Python-side config dict (vocab, hidden, classes, ...).
    pub config: Value,
    pub dir: PathBuf,
}

impl ModelMeta {
    /// Load variant metadata from `artifacts/meta.json`.
    pub fn load(artifacts_dir: &Path, variant: &str) -> Result<Self> {
        let meta = jsonlite::parse_file(&artifacts_dir.join("meta.json"))?;
        let v = meta
            .req("variants")?
            .get(variant)
            .with_context(|| format!("variant {variant:?} not in meta.json"))?;
        let shape = |spec: &Value| -> Result<Vec<i64>> {
            Ok(spec
                .req("shape")?
                .as_arr()
                .context("shape not array")?
                .iter()
                .map(|x| x.as_f64().unwrap_or(0.0) as i64)
                .collect())
        };
        let segments = SegmentTable::new(
            v.req("segments")?
                .as_arr()
                .context("segments not array")?
                .iter()
                .map(|s| -> Result<Segment> {
                    Ok(Segment {
                        name: s.req("name")?.as_str().context("name")?.to_string(),
                        offset: s.req("offset")?.as_usize().context("offset")?,
                        size: s.req("size")?.as_usize().context("size")?,
                        shape: s
                            .req("shape")?
                            .as_arr()
                            .context("shape")?
                            .iter()
                            .map(|x| x.as_usize().unwrap_or(0))
                            .collect(),
                    })
                })
                .collect::<Result<_>>()?,
        );
        segments.validate()?;
        let artifacts = v
            .req("artifacts")?
            .as_obj()
            .context("artifacts not object")?
            .iter()
            .map(|(k, val)| (k.clone(), val.as_str().unwrap_or("").to_string()))
            .collect();
        let x_dtype = v.req("x")?.req("dtype")?.as_str().context("dtype")?.to_string();
        let kind = v
            .get("kind")
            .and_then(|k| k.as_str())
            .map(|s| s.to_string())
            // Older meta.json files carry no kind; the input dtype
            // distinguishes the two families.
            .unwrap_or_else(|| {
                if x_dtype == "int32" {
                    "TransformerConfig".to_string()
                } else {
                    "MlpConfig".to_string()
                }
            });
        Ok(Self {
            variant: variant.to_string(),
            kind,
            params: v.req("params")?.as_usize().context("params")?,
            x_shape: shape(v.req("x")?)?,
            x_dtype,
            y_shape: shape(v.req("y")?)?,
            segments,
            artifacts,
            config: v.get("config").cloned().unwrap_or(Value::Null),
            dir: artifacts_dir.to_path_buf(),
        })
    }

    /// Numeric field of the variant config (e.g. "vocab", "classes").
    pub fn config_num(&self, key: &str) -> Option<f64> {
        self.config.get(key).and_then(|v| v.as_f64())
    }

    pub fn batch_size(&self) -> usize {
        self.x_shape.first().copied().unwrap_or(0) as usize
    }

    pub fn artifact_path(&self, kind: &str) -> Result<PathBuf> {
        let f = self
            .artifacts
            .get(kind)
            .with_context(|| format!("artifact kind {kind:?} missing"))?;
        Ok(self.dir.join(f))
    }

    /// Read the deterministic initial flat parameter vector.
    pub fn init_params(&self) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.artifact_path("init")?)?;
        anyhow::ensure!(bytes.len() == self.params * 4, "init.bin size mismatch");
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

// ---------------------------------------------------------------------------
// Model: all entry points of one variant, single-threaded
// ---------------------------------------------------------------------------

/// All entry points of one model variant (single-thread use; see
/// [`service::ModelService`] for the shared-thread version).
pub struct Model {
    pub meta: ModelMeta,
    native: NativeModel,
}

impl Model {
    pub fn load(_rt: &Runtime, artifacts_dir: &Path, variant: &str) -> Result<Self> {
        let meta = ModelMeta::load(artifacts_dir, variant)?;
        let native = Self::build_native(&meta)?;
        Ok(Self { meta, native })
    }

    fn build_native(meta: &ModelMeta) -> Result<NativeModel> {
        let num = |key: &str| -> Result<usize> {
            meta.config_num(key)
                .map(|v| v as usize)
                .with_context(|| format!("config key {key:?} missing for {}", meta.variant))
        };
        let batch = meta.batch_size();
        anyhow::ensure!(batch > 0, "empty batch dimension");
        anyhow::ensure!(
            meta.x_shape.len() == 2 && meta.x_shape[1] > 0,
            "x shape must be [batch, dim/seq], got {:?}",
            meta.x_shape
        );
        let model = match meta.kind.as_str() {
            "MlpConfig" => {
                anyhow::ensure!(meta.x_dtype == "float32", "MLP expects float32 inputs");
                NativeModel::Mlp(MlpModel {
                    batch,
                    input_dim: meta.x_shape[1] as usize,
                    hidden: num("hidden")?,
                    blocks: num("blocks")?,
                    classes: num("classes")?,
                })
            }
            "TransformerConfig" => {
                anyhow::ensure!(meta.x_dtype == "int32", "transformer expects int32 tokens");
                let d_model = num("d_model")?;
                let n_heads = num("n_heads")?;
                anyhow::ensure!(
                    n_heads > 0 && d_model % n_heads == 0,
                    "d_model must divide into heads"
                );
                let d_ff = match num("d_ff") {
                    Ok(f) if f > 0 => f,
                    _ => 4 * d_model,
                };
                NativeModel::Transformer(TransformerModel {
                    batch,
                    seq: meta.x_shape[1] as usize,
                    vocab: num("vocab")?,
                    d_model,
                    n_heads,
                    n_layers: num("n_layers")?,
                    d_ff,
                })
            }
            other => bail!("unknown model kind {other:?} for {}", meta.variant),
        };
        // Fail at load time (not first step) if the segment table does not
        // carry the parameters the kernels will address.
        for name in Self::required_segments(&model) {
            anyhow::ensure!(
                meta.segments.by_name(&name).is_some(),
                "segment {name:?} missing from meta.json for {}",
                meta.variant
            );
        }
        Ok(model)
    }

    fn required_segments(model: &NativeModel) -> Vec<String> {
        match model {
            NativeModel::Mlp(m) => {
                let mut names = vec!["in.w".into(), "in.b".into()];
                for i in 0..m.blocks {
                    for part in ["w1", "b1", "w2", "b2"] {
                        names.push(format!("block{i}.{part}"));
                    }
                }
                names.push("head.w".into());
                names.push("head.b".into());
                names
            }
            NativeModel::Transformer(t) => {
                let mut names = vec!["embed".into(), "pos".into()];
                for i in 0..t.n_layers {
                    for part in [
                        "ln1.scale", "ln1.bias", "qkv", "attn_out", "ln2.scale", "ln2.bias",
                        "ff1", "ff1_b", "ff2", "ff2_b",
                    ] {
                        names.push(format!("layer{i}.{part}"));
                    }
                }
                names.push("lnf.scale".into());
                names.push("lnf.bias".into());
                names
            }
        }
    }

    fn check_inputs(&self, params: &[f32], x: &XData, y: &[i32]) -> Result<()> {
        anyhow::ensure!(
            params.len() == self.meta.params,
            "params length {} != {}",
            params.len(),
            self.meta.params
        );
        let want_x: usize = self.meta.x_shape.iter().map(|&d| d as usize).product();
        let got_x = match x {
            XData::F32(d) => d.len(),
            XData::I32(d) => d.len(),
        };
        anyhow::ensure!(got_x == want_x, "x length {got_x} != {want_x}");
        let want_y: usize = self.meta.y_shape.iter().map(|&d| d as usize).product();
        anyhow::ensure!(y.len() == want_y, "labels length {} != {}", y.len(), want_y);
        Ok(())
    }

    /// Forward+backward: returns (loss, flat gradients).
    pub fn grad_step(&self, params: &[f32], x: &XData, y: &[i32]) -> Result<(f32, Vec<f32>)> {
        self.check_inputs(params, x, y)?;
        match (&self.native, x) {
            (NativeModel::Mlp(m), XData::F32(d)) => {
                Ok(m.grad_step(&self.meta.segments, params, d, y))
            }
            (NativeModel::Transformer(t), XData::I32(d)) => {
                Ok(t.grad_step(&self.meta.segments, params, d, y))
            }
            _ => bail!("x dtype mismatch for variant {}", self.meta.variant),
        }
    }

    /// Forward+backward over a short batch of `rows` rows — the per-device
    /// shard path of the device tier (each of k devices sees b/k rows).
    /// `rows == batch_size()` is bitwise the plain [`grad_step`]. Every
    /// native kernel parameterizes on the model's batch field, so a short
    /// batch is a cheap re-dimensioned clone, not padded inputs.
    ///
    /// [`grad_step`]: Model::grad_step
    pub fn grad_step_rows(
        &self,
        params: &[f32],
        x: &XData,
        y: &[i32],
        rows: usize,
    ) -> Result<(f32, Vec<f32>)> {
        let full = self.meta.batch_size();
        if rows == full {
            return self.grad_step(params, x, y);
        }
        anyhow::ensure!(
            rows >= 1 && rows <= full,
            "rows {rows} outside 1..={full} for variant {}",
            self.meta.variant
        );
        anyhow::ensure!(
            params.len() == self.meta.params,
            "params length {} != {}",
            params.len(),
            self.meta.params
        );
        // Per-row element counts: x_shape = [batch, dim/seq], y_shape =
        // [batch] (MLP) or [batch, seq] (LM) — drop the batch dimension.
        let per_x: usize = self.meta.x_shape.iter().skip(1).map(|&d| d as usize).product();
        let per_y: usize = self.meta.y_shape.iter().skip(1).map(|&d| d as usize).product();
        let got_x = match x {
            XData::F32(d) => d.len(),
            XData::I32(d) => d.len(),
        };
        anyhow::ensure!(got_x == rows * per_x, "x length {got_x} != {rows}x{per_x}");
        anyhow::ensure!(
            y.len() == rows * per_y,
            "labels length {} != {rows}x{per_y}",
            y.len()
        );
        match (&self.native, x) {
            (NativeModel::Mlp(m), XData::F32(d)) => {
                let mut short = m.clone();
                short.batch = rows;
                Ok(short.grad_step(&self.meta.segments, params, d, y))
            }
            (NativeModel::Transformer(t), XData::I32(d)) => {
                let mut short = t.clone();
                short.batch = rows;
                Ok(short.grad_step(&self.meta.segments, params, d, y))
            }
            _ => bail!("x dtype mismatch for variant {}", self.meta.variant),
        }
    }

    /// Evaluation: returns (loss, #correct predictions in batch).
    pub fn eval_step(&self, params: &[f32], x: &XData, y: &[i32]) -> Result<(f32, i32)> {
        self.check_inputs(params, x, y)?;
        match (&self.native, x) {
            (NativeModel::Mlp(m), XData::F32(d)) => {
                Ok(m.eval_step(&self.meta.segments, params, d, y))
            }
            (NativeModel::Transformer(t), XData::I32(d)) => {
                Ok(t.eval_step(&self.meta.segments, params, d, y))
            }
            _ => bail!("x dtype mismatch for variant {}", self.meta.variant),
        }
    }

    /// Fused SGD update (the math of the `sgd_update` Pallas kernel):
    /// `g_eff = rescale*g + wd*w; m = momentum*m + g_eff; w -= lr*m`.
    pub fn sgd_update(
        &self,
        w: &mut Vec<f32>,
        g: &[f32],
        m: &mut Vec<f32>,
        hyper: &crate::optimizer::SgdHyper,
    ) -> Result<()> {
        anyhow::ensure!(w.len() == g.len() && w.len() == m.len(), "sgd length mismatch");
        // Element-parallel: each element's update is independent, so
        // the partitioning is bitwise-invisible.
        let work = w.len() * 4;
        par::par_rows2(w, m, g.len(), work, |e0, wc, mc| {
            let gs = &g[e0..e0 + wc.len()];
            for ((wv, mv), &gv) in wc.iter_mut().zip(mc.iter_mut()).zip(gs) {
                let g_eff = hyper.rescale * gv + hyper.weight_decay * *wv;
                *mv = hyper.momentum * *mv + g_eff;
                *wv -= hyper.lr * *mv;
            }
        });
        Ok(())
    }

    /// Server-side elastic update (eq. 2): `center += alpha (w - center)`.
    pub fn elastic1(&self, center: &mut Vec<f32>, w: &[f32], alpha: f32) -> Result<()> {
        anyhow::ensure!(center.len() == w.len(), "elastic1 length mismatch");
        for i in 0..center.len() {
            center[i] += alpha * (w[i] - center[i]);
        }
        Ok(())
    }

    /// Client-side elastic update (eq. 3): `w -= alpha (w - center)`.
    pub fn elastic2(&self, w: &mut Vec<f32>, center: &[f32], alpha: f32) -> Result<()> {
        anyhow::ensure!(w.len() == center.len(), "elastic2 length mismatch");
        for i in 0..w.len() {
            w[i] -= alpha * (w[i] - center[i]);
        }
        Ok(())
    }
}
