//! Minimal JSON parser/serializer (no external crates are available in the
//! offline build environment, so `meta.json`, experiment configs and result
//! files go through this ~300-line implementation).
//!
//! Supports the full JSON grammar we emit and consume: objects (insertion
//! order preserved), arrays, strings with escapes, f64 numbers, booleans,
//! null. Not a general-purpose library: numbers are f64 (fine for our
//! metadata: sizes < 2^53) and \uXXXX escapes outside the BMP are rejected.

use std::collections::VecDeque;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    // ---------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key {key:?} in {}", self.kind()))
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(xs) => xs.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(kvs) => Some(kvs),
            _ => None,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
        }
    }

    // --------------------------------------------------------- builders

    pub fn obj(kvs: Vec<(&str, Value)>) -> Value {
        Value::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Value {
        Value::Num(x)
    }

    pub fn str(s: &str) -> Value {
        Value::Str(s.to_string())
    }

    // ------------------------------------------------------- serializing

    /// Compact serialization.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_json_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    x.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Value::Obj(kvs) => {
                if kvs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ------------------------------------------------------------- parsing

/// Parse a JSON document.
pub fn parse(text: &str) -> anyhow::Result<Value> {
    let mut p = Parser {
        chars: text.chars().collect(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(p.pos == p.chars.len(), "trailing garbage at {}", p.pos);
    Ok(v)
}

/// Parse the JSON file at `path`.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text)
}

struct Parser {
    chars: VecDeque<char>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn next(&mut self) -> anyhow::Result<char> {
        let c = self.peek().ok_or_else(|| anyhow::anyhow!("unexpected EOF"))?;
        self.pos += 1;
        Ok(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t' | '\n' | '\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: char) -> anyhow::Result<()> {
        let got = self.next()?;
        anyhow::ensure!(got == c, "expected {c:?} got {got:?} at {}", self.pos);
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Value) -> anyhow::Result<Value> {
        for c in word.chars() {
            self.expect(c)?;
        }
        Ok(v)
    }

    fn value(&mut self) -> anyhow::Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some('{') => self.object(),
            Some('[') => self.array(),
            Some('"') => Ok(Value::Str(self.string()?)),
            Some('t') => self.lit("true", Value::Bool(true)),
            Some('f') => self.lit("false", Value::Bool(false)),
            Some('n') => self.lit("null", Value::Null),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {other:?} at {}", self.pos),
        }
    }

    fn object(&mut self) -> anyhow::Result<Value> {
        self.expect('{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.pos += 1;
            return Ok(Value::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(':')?;
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.next()? {
                ',' => continue,
                '}' => return Ok(Value::Obj(kvs)),
                c => anyhow::bail!("expected , or }} got {c:?}"),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Value> {
        self.expect('[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(']') {
            self.pos += 1;
            return Ok(Value::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.next()? {
                ',' => continue,
                ']' => return Ok(Value::Arr(xs)),
                c => anyhow::bail!("expected , or ] got {c:?}"),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect('"')?;
        let mut s = String::new();
        loop {
            match self.next()? {
                '"' => return Ok(s),
                '\\' => match self.next()? {
                    '"' => s.push('"'),
                    '\\' => s.push('\\'),
                    '/' => s.push('/'),
                    'n' => s.push('\n'),
                    't' => s.push('\t'),
                    'r' => s.push('\r'),
                    'b' => s.push('\u{8}'),
                    'f' => s.push('\u{c}'),
                    'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.next()?;
                            code = code * 16
                                + c.to_digit(16)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u digit {c:?}"))?;
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| anyhow::anyhow!("bad codepoint {code}"))?,
                        );
                    }
                    c => anyhow::bail!("bad escape \\{c}"),
                },
                c => s.push(c),
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Value> {
        let start = self.pos;
        if self.peek() == Some('-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || "+-.eE".contains(c)) {
            self.pos += 1;
        }
        let text: String = self.chars.iter().skip(start).take(self.pos - start).collect();
        Ok(Value::Num(text.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number {text:?}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c").unwrap().as_obj().unwrap().len(), 0);
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ A"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = Value::obj(vec![
            ("name", Value::str("mlp")),
            ("params", Value::num(4324.0)),
            ("list", Value::Arr(vec![Value::num(1.0), Value::Bool(false), Value::Null])),
        ]);
        for text in [v.to_json(), v.to_json_pretty()] {
            assert_eq!(parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn object_order_preserved() {
        let v = parse(r#"{"z": 1, "a": 2}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a"]);
    }

    #[test]
    fn errors_on_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn req_reports_missing_key() {
        let v = parse("{}").unwrap();
        let err = v.req("params").unwrap_err().to_string();
        assert!(err.contains("params"));
    }

    #[test]
    fn float_formatting_integers_clean() {
        assert_eq!(Value::num(5.0).to_json(), "5");
        assert_eq!(Value::num(0.25).to_json(), "0.25");
    }
}
