//! Synthetic datasets — the ImageNet-1K substitute (DESIGN.md §2).
//!
//! The paper trains ResNet-50 on ImageNet (1.2M images / 1000 classes).
//! Neither the data nor the GPUs to chew it exist here, so convergence
//! experiments use a deterministic **Gaussian-mixture image classifier
//! task**: class-conditional Gaussian blobs in pixel space, separable but
//! noisy, so SGD shows a real learning curve whose dynamics (variance
//! reduction from bigger effective mini-batches, staleness penalties for
//! async updates) are the properties the paper's figures exercise.
//!
//! For the transformer end-to-end driver there is a tiny synthetic corpus
//! with learnable bigram/trigram structure.

use crate::util::Rng;

/// A batch of dense features + integer labels.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub batch: usize,
}

/// Class-conditional Gaussian mixture over `dim` "pixels".
///
/// Deterministic: sample `i` of the dataset is fully determined by
/// `(seed, i)`, so any worker can materialize any shard without storing
/// 336 GB of JPEGs.
#[derive(Debug, Clone)]
pub struct GaussianMixture {
    pub dim: usize,
    pub classes: usize,
    pub noise: f32,
    pub seed: u64,
    centers: Vec<f32>, // classes x dim
}

impl GaussianMixture {
    pub fn new(dim: usize, classes: usize, noise: f32, seed: u64) -> Self {
        // Class centers: unit-ish random directions, fixed by the seed.
        let mut rng = Rng::new(seed).fork(0xC0FFEE);
        let mut centers = vec![0.0f32; classes * dim];
        rng.fill_normal(&mut centers, 0.0, 1.0);
        // Normalize each center to comparable energy.
        for c in 0..classes {
            let row = &mut centers[c * dim..(c + 1) * dim];
            let norm = (row.iter().map(|v| v * v).sum::<f32>()).sqrt().max(1e-6);
            for v in row.iter_mut() {
                *v /= norm / (dim as f32).sqrt();
            }
        }
        Self { dim, classes, noise, seed, centers }
    }

    /// Materialize sample `i`: label is `i % classes`; features are the
    /// class center *attenuated by the noise level* plus unit Gaussian
    /// noise: `x = center/noise + N(0, 1)`.
    ///
    /// Keeping the additive noise at unit scale keeps inputs ~N(0,1) (so
    /// learning rates stay comparable across difficulty levels) while
    /// `noise` controls the signal-to-noise ratio — large values make the
    /// task take many epochs, like ImageNet does. `noise == 0` yields the
    /// exact centers (useful in tests).
    pub fn sample(&self, i: u64, x: &mut [f32]) -> i32 {
        debug_assert_eq!(x.len(), self.dim);
        let label = (i % self.classes as u64) as usize;
        let mut rng = Rng::new(self.seed).fork(i.wrapping_mul(0x9E3779B97F4A7C15) | 1);
        let center = &self.centers[label * self.dim..(label + 1) * self.dim];
        if self.noise <= 0.0 {
            x.copy_from_slice(center);
            return label as i32;
        }
        let signal = 1.0 / self.noise;
        for (j, v) in x.iter_mut().enumerate() {
            *v = center[j] * signal + rng.normal() as f32;
        }
        label as i32
    }

    /// Materialize a batch of consecutive sample indices.
    pub fn batch(&self, start: u64, batch: usize) -> Batch {
        let mut x = vec![0.0f32; batch * self.dim];
        let mut y = vec![0i32; batch];
        for b in 0..batch {
            y[b] = self.sample(start + b as u64, &mut x[b * self.dim..(b + 1) * self.dim]);
        }
        Batch { x, y, batch }
    }
}

/// A worker's shard of an epoch: which sample indices it owns.
///
/// Mirrors MXNET data-parallel sharding: the epoch's `total` samples are
/// split contiguously across `n_workers`; each worker iterates its shard in
/// `batch`-sized steps (the *batch size* is MXNET's scheduling unit, §5 —
/// distinct from the algorithm's mini_batch_size).
#[derive(Debug, Clone)]
pub struct Shard {
    pub worker: usize,
    pub n_workers: usize,
    pub total: u64,
    pub batch: usize,
    pub epoch: u64,
}

impl Shard {
    /// Number of batches this worker runs per epoch.
    pub fn batches_per_epoch(&self) -> u64 {
        let per_worker = self.total / self.n_workers as u64;
        per_worker / self.batch as u64
    }

    /// Start index of batch `b` in epoch `epoch` for this worker.
    /// Epochs rotate the shard assignment so every worker eventually sees
    /// different data (a cheap stand-in for reshuffling).
    ///
    /// Every batch stays inside the training range `[0, total)`, and —
    /// whenever the shard can hold one batch (`per_worker >= batch`) —
    /// strictly inside this worker's shard: a batch index past
    /// [`Shard::batches_per_epoch`] wraps by *whole batches* (re-running
    /// the shard) and the start is clamped so the final batch never
    /// crosses the shard boundary. The old `(b * batch) % per_worker`
    /// wrapped mid-stride when `batch` did not divide `per_worker`,
    /// sampling a neighbor's shard (double-counted under epoch rotation)
    /// or past the training range entirely. When the shard is *smaller*
    /// than one batch (a degenerate config), batches necessarily overlap
    /// neighbors, but the final clamp keeps them off the held-out range.
    pub fn batch_start(&self, b: u64) -> u64 {
        let per_worker = (self.total / self.n_workers as u64).max(1);
        let rotated = (self.worker as u64 + self.epoch) % self.n_workers as u64;
        let bpe = (per_worker / self.batch as u64).max(1);
        let offset = (b % bpe) * self.batch as u64;
        let offset = offset.min(per_worker.saturating_sub(self.batch as u64));
        let start = rotated * per_worker + offset;
        start.min(self.total.saturating_sub(self.batch as u64))
    }
}

/// Synthetic token corpus for the transformer: a seeded random walk over a
/// cyclic vocabulary with strong local structure (next token is one of a
/// few seeded successors), so an LM can actually reduce loss below uniform.
#[derive(Debug, Clone)]
pub struct TinyCorpus {
    pub vocab: usize,
    pub seed: u64,
    succ: Vec<u32>, // vocab x BRANCH successor table
}

const BRANCH: usize = 4;

impl TinyCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed).fork(0x7E47);
        let succ = (0..vocab * BRANCH)
            .map(|_| rng.below(vocab as u64) as u32)
            .collect();
        Self { vocab, seed, succ }
    }

    /// Generate a (tokens, next-tokens) pair of length `seq` for sample `i`.
    pub fn sample(&self, i: u64, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(self.seed).fork(i.wrapping_mul(0xD1B54A32D192ED03) | 1);
        let mut tok = rng.below(self.vocab as u64) as u32;
        let mut xs = Vec::with_capacity(seq);
        let mut ys = Vec::with_capacity(seq);
        for _ in 0..seq {
            xs.push(tok as i32);
            let next = self.succ[tok as usize * BRANCH + rng.below(BRANCH as u64) as usize];
            ys.push(next as i32);
            tok = next;
        }
        (xs, ys)
    }

    /// Batch of `batch` sequences starting at sample index `start`.
    pub fn batch(&self, start: u64, batch: usize, seq: usize) -> Batch {
        let mut x = Vec::with_capacity(batch * seq);
        let mut y = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let (xs, ys) = self.sample(start + b as u64, seq);
            x.extend(xs);
            y.extend(ys);
        }
        Batch {
            x: x.iter().map(|&t| t as f32).collect(), // carried as f32 slots
            y,
            batch,
        }
    }

    /// Same as [`batch`] but keeping tokens as i32 (the model's input dtype).
    pub fn batch_tokens(&self, start: u64, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut x = Vec::with_capacity(batch * seq);
        let mut y = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let (xs, ys) = self.sample(start + b as u64, seq);
            x.extend(xs);
            y.extend(ys);
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_mixture_deterministic() {
        let d = GaussianMixture::new(16, 4, 0.5, 42);
        let mut a = vec![0.0; 16];
        let mut b = vec![0.0; 16];
        let la = d.sample(7, &mut a);
        let lb = d.sample(7, &mut b);
        assert_eq!(la, lb);
        assert_eq!(a, b);
    }

    #[test]
    fn labels_cycle_over_classes() {
        let d = GaussianMixture::new(8, 4, 0.1, 1);
        let mut x = vec![0.0; 8];
        assert_eq!(d.sample(0, &mut x), 0);
        assert_eq!(d.sample(5, &mut x), 1);
        assert_eq!(d.sample(11, &mut x), 3);
    }

    #[test]
    fn noise_zero_gives_exact_centers() {
        let d = GaussianMixture::new(8, 2, 0.0, 3);
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        d.sample(0, &mut a); // class 0
        d.sample(2, &mut b); // class 0 again
        assert_eq!(a, b);
    }

    #[test]
    fn different_samples_differ() {
        let d = GaussianMixture::new(8, 2, 0.5, 3);
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        d.sample(0, &mut a);
        d.sample(2, &mut b); // same class, different noise
        assert_ne!(a, b);
    }

    #[test]
    fn batch_layout() {
        let d = GaussianMixture::new(4, 2, 0.1, 5);
        let b = d.batch(10, 3);
        assert_eq!(b.x.len(), 12);
        assert_eq!(b.y.len(), 3);
        assert_eq!(b.y, vec![0, 1, 0]);
    }

    #[test]
    fn shard_partitions_epoch() {
        let total = 1200u64;
        let nw = 12;
        let batch = 10;
        let sh = |w| Shard { worker: w, n_workers: nw, total, batch, epoch: 0 };
        assert_eq!(sh(0).batches_per_epoch(), 10);
        // Worker starts are disjoint contiguous ranges at epoch 0.
        let starts: Vec<u64> = (0..nw).map(|w| sh(w).batch_start(0)).collect();
        for (w, s) in starts.iter().enumerate() {
            assert_eq!(*s, w as u64 * 100);
        }
    }

    #[test]
    fn shard_batches_disjoint_and_in_range_even_when_batch_misdivides() {
        // Property: over every worker and every in-epoch batch index, the
        // [start, start+batch) ranges are pairwise disjoint and inside
        // [0, total) — including shapes where batch does not divide the
        // per-worker shard (the old modulo wrapped mid-stride and crossed
        // shard boundaries) and indices past batches_per_epoch.
        for (total, nw, batch) in [
            (1200u64, 12usize, 10usize),
            (1000, 3, 30),  // per_worker 333, batch !| per_worker
            (700, 4, 32),   // per_worker 175
            (64, 5, 7),     // per_worker 12
            (97, 2, 13),    // odd everything
        ] {
            for epoch in [0u64, 1, 3] {
                let mut ranges: Vec<(u64, u64)> = Vec::new();
                for w in 0..nw {
                    let sh = Shard { worker: w, n_workers: nw, total, batch, epoch };
                    let bpe = sh.batches_per_epoch();
                    for b in 0..bpe {
                        let s = sh.batch_start(b);
                        let e = s + batch as u64;
                        assert!(
                            e <= total,
                            "total={total} nw={nw} batch={batch} w={w} b={b}: \
                             [{s}, {e}) leaves the training range"
                        );
                        ranges.push((s, e));
                    }
                    // Past-the-epoch indices wrap by whole batches and stay
                    // inside this worker's shard.
                    let per_worker = total / nw as u64;
                    let lo = ((w as u64 + epoch) % nw as u64) * per_worker;
                    for b in [bpe, bpe + 1, 2 * bpe + 3] {
                        let s = sh.batch_start(b);
                        assert!(
                            s >= lo && s + (batch as u64) <= lo + per_worker,
                            "wrapped batch b={b} of worker {w} left its shard"
                        );
                    }
                }
                ranges.sort_unstable();
                for pair in ranges.windows(2) {
                    assert!(
                        pair[0].1 <= pair[1].0,
                        "total={total} nw={nw} batch={batch}: overlap {pair:?}"
                    );
                }
            }
        }
        // Degenerate shapes (shard smaller than one batch): disjointness
        // is impossible, but every batch must still stay inside the
        // training range — never into the held-out indices.
        for (total, nw, batch) in [(10u64, 4usize, 7usize), (5, 8, 3), (6, 2, 8)] {
            for epoch in [0u64, 2] {
                for w in 0..nw {
                    let sh = Shard { worker: w, n_workers: nw, total, batch, epoch };
                    for b in [0u64, 1, 5] {
                        let s = sh.batch_start(b);
                        assert!(
                            s + (batch as u64) <= total.max(batch as u64),
                            "degenerate total={total} nw={nw} batch={batch} w={w}: start {s}"
                        );
                        assert!(s <= total.saturating_sub(batch as u64));
                    }
                }
            }
        }
    }

    #[test]
    fn shard_rotates_across_epochs() {
        let a = Shard { worker: 0, n_workers: 4, total: 400, batch: 10, epoch: 0 };
        let b = Shard { worker: 0, n_workers: 4, total: 400, batch: 10, epoch: 1 };
        assert_ne!(a.batch_start(0), b.batch_start(0));
    }

    #[test]
    fn corpus_deterministic_and_learnable() {
        let c = TinyCorpus::new(64, 9);
        let (x1, y1) = c.sample(3, 32);
        let (x2, y2) = c.sample(3, 32);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        // Chain property: x[t+1] == y[t].
        for t in 0..31 {
            assert_eq!(x1[t + 1], y1[t]);
        }
        // Every successor is from the token's BRANCH-entry table => the
        // conditional entropy is at most log2(BRANCH) << log2(vocab).
        for t in 0..32 {
            let tok = x1[t] as usize;
            let succs = &c.succ[tok * BRANCH..(tok + 1) * BRANCH];
            assert!(succs.contains(&(y1[t] as u32)));
        }
    }

    #[test]
    fn corpus_batch_tokens_shapes() {
        let c = TinyCorpus::new(32, 1);
        let (x, y) = c.batch_tokens(0, 4, 16);
        assert_eq!(x.len(), 64);
        assert_eq!(y.len(), 64);
        assert!(x.iter().all(|&t| t >= 0 && t < 32));
    }
}
